//! Benchmark runner (replaces criterion; `cargo bench` targets set
//! `harness = false` and drive this).
//!
//! Mirrors the paper's measurement protocol at the harness level: warmup
//! iterations, N timed iterations, and robust central statistics
//! (median + median-5 mean) so one-off scheduler hiccups don't skew rows.

use std::time::Instant;

use crate::util::stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub median_us: f64,
    pub p95_us: f64,
    pub min_us: f64,
    pub max_us: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "| {} | {} | {:.1} | {:.1} | {:.1} | {:.1} | {:.1} |",
            self.name, self.iters, self.min_us, self.median_us, self.mean_us,
            self.p95_us, self.max_us
        )
    }
}

/// A group of benchmark cases rendered as one markdown table.
pub struct Bench {
    title: String,
    warmup: usize,
    iters: usize,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(title: &str) -> Self {
        // Keep bench runtime bounded on the 1-core CI box; override per-case
        // via with_iters when a workload is very fast/slow.
        Bench { title: title.to_string(), warmup: 3, iters: 10, results: Vec::new() }
    }

    pub fn with_iters(mut self, warmup: usize, iters: usize) -> Self {
        self.warmup = warmup;
        self.iters = iters;
        self
    }

    /// Time `f` (called once per iteration); records robust stats.
    pub fn case<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_us: stats::median5_mean(&samples),
            median_us: stats::median(&samples),
            p95_us: stats::percentile(&samples, 95.0),
            min_us: stats::min(&samples),
            max_us: stats::max(&samples),
        });
        self.results.last().unwrap()
    }

    /// Render the whole group as a markdown table.
    pub fn report(&self) -> String {
        let mut s = format!(
            "## {}\n\n| case | iters | min µs | median µs | mean(med5) µs | p95 µs | max µs |\n|---|---|---|---|---|---|---|\n",
            self.title
        );
        for r in &self.results {
            s.push_str(&r.row());
            s.push('\n');
        }
        s
    }

    /// Print to stdout and also persist under reports/ for EXPERIMENTS.md.
    pub fn finish(&self) {
        let rep = self.report();
        println!("{rep}");
        let fname = format!(
            "reports/bench_{}.md",
            self.title.to_lowercase().replace([' ', '/', '(', ')'], "_")
        );
        if std::fs::create_dir_all("reports").is_ok() {
            let _ = std::fs::write(&fname, &rep);
        }
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_all_cases() {
        let mut b = Bench::new("unit").with_iters(1, 3);
        b.case("noop", || {
            black_box(1 + 1);
        });
        b.case("spin", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            black_box(s);
        });
        assert_eq!(b.results().len(), 2);
        assert!(b.results()[0].min_us <= b.results()[0].max_us);
    }

    #[test]
    fn report_is_markdown_table() {
        let mut b = Bench::new("unit2").with_iters(0, 2);
        b.case("x", || {
            black_box(0);
        });
        let rep = b.report();
        assert!(rep.contains("## unit2"));
        assert!(rep.contains("| x | 2 |"));
        assert!(rep.lines().filter(|l| l.starts_with('|')).count() >= 3);
    }

    #[test]
    fn stats_ordering_invariant() {
        let mut b = Bench::new("unit3").with_iters(0, 8);
        b.case("work", || {
            let mut v: Vec<u64> = (0..2000).rev().collect();
            v.sort();
            black_box(v);
        });
        let r = &b.results()[0];
        assert!(r.min_us <= r.median_us && r.median_us <= r.max_us);
        assert!(r.p95_us <= r.max_us + 1e-9);
    }
}
