//! Declarative command-line flag parsing (replaces clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! args, and generates `--help` text from declared options.

use std::collections::BTreeMap;

/// One declared option.
#[derive(Clone, Debug)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    /// Option names the user actually typed (vs defaulted).
    explicit: Vec<String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown option --{0}")]
    Unknown(String),
    #[error("option --{0} requires a value")]
    MissingValue(String),
    #[error("invalid value for --{0}: {1}")]
    BadValue(String, String),
}

/// Declarative spec: a named subcommand with options.
pub struct Spec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
}

impl Spec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Spec { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: true });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let d = match (&o.default, o.is_flag) {
                (_, true) => String::new(),
                (Some(d), _) => format!(" [default: {d}]"),
                (None, _) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
        }
        s
    }

    /// Parse raw argv (without the program/subcommand names).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        // seed defaults
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?;
                if opt.is_flag {
                    args.flags.push(name);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.clone()))?
                        }
                    };
                    args.explicit.push(name.clone());
                    args.values.insert(name, v);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> String {
        self.get(name).unwrap_or_default().to_string()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Was this option typed by the user (vs filled from its default)?
    pub fn is_explicit(&self, name: &str) -> bool {
        self.explicit.iter().any(|f| f == name)
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        let v = self.get(name).ok_or_else(|| CliError::MissingValue(name.into()))?;
        v.parse().map_err(|_| CliError::BadValue(name.into(), v.into()))
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        let v = self.get(name).ok_or_else(|| CliError::MissingValue(name.into()))?;
        v.parse().map_err(|_| CliError::BadValue(name.into(), v.into()))
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        let v = self.get(name).ok_or_else(|| CliError::MissingValue(name.into()))?;
        v.parse().map_err(|_| CliError::BadValue(name.into(), v.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec::new("demo", "test spec")
            .opt("platform", "perlmutter", "target platform")
            .opt("seed", "42", "rng seed")
            .req("model", "model preset")
            .flag("verbose", "log more")
    }

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_applied() {
        let a = spec().parse(&argv(&["--model", "gpt20b"])).unwrap();
        assert_eq!(a.str("platform"), "perlmutter");
        assert_eq!(a.usize("seed").unwrap(), 42);
        assert_eq!(a.str("model"), "gpt20b");
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn explicit_vs_defaulted_options() {
        let a = spec().parse(&argv(&["--model", "gpt20b", "--seed=7"])).unwrap();
        assert!(a.is_explicit("model"));
        assert!(a.is_explicit("seed"));
        assert!(!a.is_explicit("platform")); // defaulted, not typed
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = spec()
            .parse(&argv(&["--model=llama13b", "--seed=7", "--verbose"]))
            .unwrap();
        assert_eq!(a.str("model"), "llama13b");
        assert_eq!(a.u64("seed").unwrap(), 7);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            spec().parse(&argv(&["--nope", "x"])),
            Err(CliError::Unknown(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            spec().parse(&argv(&["--model"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn bad_numeric_value() {
        let a = spec().parse(&argv(&["--model", "m", "--seed", "xyz"])).unwrap();
        assert!(matches!(a.usize("seed"), Err(CliError::BadValue(_, _))));
    }

    #[test]
    fn positional_collected() {
        let a = spec().parse(&argv(&["--model", "m", "extra1", "extra2"])).unwrap();
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn help_mentions_options() {
        let h = spec().help_text();
        assert!(h.contains("--platform"));
        assert!(h.contains("required"));
        assert!(h.contains("default: 42"));
    }
}
