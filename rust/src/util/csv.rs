//! Tiny CSV reader/writer for persisted micro-benchmark datasets
//! (`fgpm collect` output, consumed by `fgpm train`).
//!
//! The dialect is deliberately simple — numeric cells plus a header row of
//! bare identifiers — because we only persist our own datasets. Quoting is
//! supported on read for robustness, never emitted on write.

use std::fmt::Write as _;
use std::path::Path;

/// A numeric table with named columns.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Table {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

#[derive(Debug, thiserror::Error)]
pub enum CsvError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("line {0}: expected {1} cells, got {2}")]
    Ragged(usize, usize, usize),
    #[error("line {0}: bad number '{1}'")]
    BadNumber(usize, String),
    #[error("empty csv")]
    Empty,
}

impl Table {
    pub fn new(columns: &[&str]) -> Table {
        Table { columns: columns.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        assert_eq!(row.len(), self.columns.len(), "row arity");
        self.rows.push(row);
    }

    pub fn col_index(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|c| c == name)
    }

    /// Extract one column by name.
    pub fn col(&self, name: &str) -> Option<Vec<f64>> {
        let i = self.col_index(name)?;
        Some(self.rows.iter().map(|r| r[i]).collect())
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.columns.join(",");
        s.push('\n');
        for r in &self.rows {
            let mut first = true;
            for x in r {
                if !first {
                    s.push(',');
                }
                first = false;
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(s, "{}", *x as i64);
                } else {
                    let _ = write!(s, "{x}");
                }
            }
            s.push('\n');
        }
        s
    }

    pub fn save(&self, path: &Path) -> Result<(), CsvError> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }

    pub fn parse(text: &str) -> Result<Table, CsvError> {
        let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
        let (_, header) = lines.next().ok_or(CsvError::Empty)?;
        let columns: Vec<String> = split_line(header).into_iter().collect();
        let mut rows = Vec::new();
        for (ln, line) in lines {
            let cells = split_line(line);
            if cells.len() != columns.len() {
                return Err(CsvError::Ragged(ln + 1, columns.len(), cells.len()));
            }
            let mut row = Vec::with_capacity(cells.len());
            for c in cells {
                row.push(
                    c.trim()
                        .parse::<f64>()
                        .map_err(|_| CsvError::BadNumber(ln + 1, c.clone()))?,
                );
            }
            rows.push(row);
        }
        Ok(Table { columns, rows })
    }

    pub fn load(path: &Path) -> Result<Table, CsvError> {
        Table::parse(&std::fs::read_to_string(path)?)
    }
}

fn split_line(line: &str) -> Vec<String> {
    let mut cells = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    for c in line.chars() {
        match c {
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                cells.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    cells.push(cur);
    cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut t = Table::new(&["a", "b", "lat_us"]);
        t.push(vec![1.0, 2.0, 3.25]);
        t.push(vec![4.0, 5.0, 6.0]);
        let t2 = Table::parse(&t.to_csv()).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn col_extraction() {
        let mut t = Table::new(&["x", "y"]);
        t.push(vec![1.0, 10.0]);
        t.push(vec![2.0, 20.0]);
        assert_eq!(t.col("y").unwrap(), vec![10.0, 20.0]);
        assert!(t.col("z").is_none());
    }

    #[test]
    fn integers_written_clean() {
        let mut t = Table::new(&["n"]);
        t.push(vec![42.0]);
        assert!(t.to_csv().contains("\n42\n"));
    }

    #[test]
    fn ragged_rejected() {
        assert!(matches!(
            Table::parse("a,b\n1,2,3\n"),
            Err(CsvError::Ragged(2, 2, 3))
        ));
    }

    #[test]
    fn bad_number_rejected() {
        assert!(matches!(
            Table::parse("a\nxyz\n"),
            Err(CsvError::BadNumber(2, _))
        ));
    }

    #[test]
    fn blank_lines_skipped() {
        let t = Table::parse("a,b\n\n1,2\n\n3,4\n").unwrap();
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn quoted_cells() {
        let t = Table::parse("a,b\n\"1\",2\n").unwrap();
        assert_eq!(t.rows[0], vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_enforced_on_push() {
        let mut t = Table::new(&["a"]);
        t.push(vec![1.0, 2.0]);
    }

    #[test]
    fn save_load_file() {
        let dir = std::env::temp_dir().join("fgpm_csv_test");
        let path = dir.join("t.csv");
        let mut t = Table::new(&["q"]);
        t.push(vec![7.5]);
        t.save(&path).unwrap();
        assert_eq!(Table::load(&path).unwrap(), t);
        let _ = std::fs::remove_dir_all(dir);
    }
}
