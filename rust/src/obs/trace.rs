//! Chrome trace-event JSON renderers.
//!
//! Format reference: the Trace Event Format accepted by Perfetto and
//! `chrome://tracing` — `"X"` complete events with `ts`/`dur`, `"M"`
//! metadata events naming processes/threads, and `"s"`/`"f"` flow pairs
//! drawing send→recv arrows. All timestamps are µs.
//!
//! Determinism contract (relied on by `tests/golden_traces.rs` and the
//! `GOLDEN_REGEN=1` staleness gate): for a given [`Schedule`] the output
//! bytes are a pure function of the schedule matrices — events are
//! emitted in a fixed pass order, stably sorted by (pid, tid, ts), and
//! serialized through [`Json`]'s sorted-key writer.

use crate::pipeline::Schedule;
use crate::util::json::Json;

use super::span::SpanRecord;

fn meta(pid: usize, tid: usize, what: &str, name: String) -> Json {
    Json::obj(vec![
        ("args", Json::obj(vec![("name", Json::Str(name))])),
        ("name", Json::Str(what.to_string())),
        ("ph", Json::Str("M".into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(0.0)),
    ])
}

#[allow(clippy::too_many_arguments)]
fn complete(pid: usize, tid: usize, ts: f64, dur: f64, cat: &str, name: String, mb: usize) -> Json {
    Json::obj(vec![
        ("args", Json::obj(vec![("mb", Json::Num(mb as f64))])),
        ("cat", Json::Str(cat.to_string())),
        ("dur", Json::Num(dur)),
        ("name", Json::Str(name)),
        ("ph", Json::Str("X".into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts)),
    ])
}

fn flow(pid: usize, tid: usize, ts: f64, ph: &str, id: usize) -> Json {
    let mut fields = vec![
        ("cat", Json::Str("P2P".into())),
        ("id", Json::Num(id as f64)),
        ("name", Json::Str("p2p".into())),
        ("ph", Json::Str(ph.to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts)),
    ];
    if ph == "f" {
        // bind to the enclosing slice's end so arrows land on the task
        fields.push(("bp", Json::Str("e".into())));
    }
    Json::obj(fields)
}

/// Who consumes the transfer leaving task (s, c) in the given direction:
/// forward activations flow down the pipeline (wrapping to the next
/// chunk for interleaved walks), input gradients flow back up (wrapping
/// to the previous chunk). `None` for the terminal task with no
/// consumer — the executor pins `arrive == end` there, so no P2P event
/// is emitted either way.
fn receiver(fwd: bool, s: usize, c: usize, stages: usize, chunks: usize) -> Option<(usize, usize)> {
    if fwd {
        if s + 1 < stages {
            Some((s + 1, c))
        } else if c + 1 < chunks {
            Some((0, c + 1))
        } else {
            None
        }
    } else if s > 0 {
        Some((s - 1, c))
    } else if c > 0 {
        Some((stages - 1, c - 1))
    } else {
        None
    }
}

/// Render an executed schedule as a complete trace: pipeline ranks
/// become processes, virtual-stage chunks become threads, and every
/// exposed boundary crossing gets a P2P slice on the sender's track plus
/// a flow arrow to the consuming task's arrival instant.
pub fn schedule_trace_json(label: &str, sched: &Schedule) -> Json {
    let stages = sched.stages();
    let m = sched.micro_batches();
    let chunks = sched.chunks;
    // (pid, tid, ts, event) — sorted stably at the end so ties keep
    // this emission order (metadata first, then F, B, W passes)
    let mut evs: Vec<(usize, usize, f64, Json)> = Vec::new();

    for s in 0..stages {
        evs.push((s, 0, 0.0, meta(s, 0, "process_name", format!("rank {s}"))));
        for c in 0..chunks {
            evs.push((s, c, 0.0, meta(s, c, "thread_name", format!("stage {s} chunk {c}"))));
        }
    }

    // pass 2: forward tasks + their exposed sends
    for s in 0..stages {
        for c in 0..chunks {
            for i in 0..m {
                let idx = c * m + i;
                let (ts, te) = (sched.fwd_start[s][idx], sched.fwd_end[s][idx]);
                evs.push((s, c, ts, complete(s, c, ts, te - ts, "F", format!("F{i}"), i)));
                let arrive = sched.fwd_arrive[s][idx];
                if let Some((rs, rc)) = receiver(true, s, c, stages, chunks) {
                    if arrive > te {
                        let id = (s * chunks + c) * m + i;
                        evs.push((s, c, te, complete(s, c, te, arrive - te, "P2P", format!("send F{i}"), i)));
                        evs.push((s, c, te, flow(s, c, te, "s", id)));
                        evs.push((rs, rc, arrive, flow(rs, rc, arrive, "f", id)));
                    }
                }
            }
        }
    }

    // pass 3: backward tasks + their exposed sends
    for s in 0..stages {
        for c in 0..chunks {
            for i in 0..m {
                let idx = c * m + i;
                let (ts, te) = (sched.bwd_start[s][idx], sched.bwd_end[s][idx]);
                evs.push((s, c, ts, complete(s, c, ts, te - ts, "B", format!("B{i}"), i)));
                let arrive = sched.bwd_arrive[s][idx];
                if let Some((rs, rc)) = receiver(false, s, c, stages, chunks) {
                    if arrive > te {
                        let id = stages * chunks * m + (s * chunks + c) * m + i;
                        evs.push((s, c, te, complete(s, c, te, arrive - te, "P2P", format!("send B{i}"), i)));
                        evs.push((s, c, te, flow(s, c, te, "s", id)));
                        evs.push((rs, rc, arrive, flow(rs, rc, arrive, "f", id)));
                    }
                }
            }
        }
    }

    // pass 4: deferred weight-grad tasks (ZB-H1 only; empty elsewhere)
    for s in 0..stages {
        for idx in 0..sched.wgt_start[s].len() {
            let (c, i) = (idx / m, idx % m);
            let (ts, te) = (sched.wgt_start[s][idx], sched.wgt_end[s][idx]);
            evs.push((s, c, ts, complete(s, c, ts, te - ts, "W", format!("W{i}"), i)));
        }
    }

    evs.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.total_cmp(&b.2)));
    Json::obj(vec![
        ("displayTimeUnit", Json::Str("ms".into())),
        ("schedule", Json::Str(label.to_string())),
        ("traceEvents", Json::Arr(evs.into_iter().map(|e| e.3).collect())),
    ])
}

/// Render drained engine spans ([`super::span::drain`]) as a trace: one
/// process ("fgpm engine"), one thread per recorder tid.
pub fn spans_to_trace_json(spans: &[SpanRecord]) -> Json {
    let mut evs: Vec<(u64, f64, Json)> = Vec::new();
    evs.push((0, 0.0, meta(0, 0, "process_name", "fgpm engine".to_string())));
    let mut seen = std::collections::BTreeSet::new();
    for sp in spans {
        if seen.insert(sp.tid) {
            evs.push((sp.tid, 0.0, meta(0, sp.tid as usize, "thread_name", format!("thread {}", sp.tid))));
        }
    }
    for sp in spans {
        evs.push((
            sp.tid,
            sp.start_us,
            Json::obj(vec![
                ("cat", Json::Str(sp.cat.to_string())),
                ("dur", Json::Num(sp.dur_us)),
                ("name", Json::Str(sp.name.clone())),
                ("ph", Json::Str("X".into())),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(sp.tid as f64)),
                ("ts", Json::Num(sp.start_us)),
            ]),
        ));
    }
    evs.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
    Json::obj(vec![
        ("displayTimeUnit", Json::Str("ms".into())),
        ("traceEvents", Json::Arr(evs.into_iter().map(|e| e.2).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{execute, ScheduleKind, TaskTimes};

    fn sched(kind: ScheduleKind) -> Schedule {
        let times = TaskTimes::uniform(4, 8, 2.0, 4.0)
            .with_sends(vec![vec![0.7; 8]; 4], vec![vec![0.9; 8]; 4])
            .with_overlap(0.5);
        execute(kind.build().as_ref(), &times).unwrap()
    }

    fn events(j: &Json) -> Vec<Json> {
        j.get("traceEvents").unwrap().as_arr().unwrap().to_vec()
    }

    #[test]
    fn schedule_trace_has_required_keys_and_sorted_tracks() {
        let j = schedule_trace_json("1f1b", &sched(ScheduleKind::OneFOneB));
        assert_eq!(j.str_at("displayTimeUnit"), Some("ms"));
        assert_eq!(j.str_at("schedule"), Some("1f1b"));
        let evs = events(&j);
        assert!(!evs.is_empty());
        let mut prev: Option<(i64, i64, f64)> = None;
        for e in &evs {
            for key in ["ph", "ts", "pid", "tid"] {
                assert!(e.get(key).is_some(), "missing {key}: {e}");
            }
            if let Some(d) = e.f64_at("dur") {
                assert!(d >= 0.0, "{e}");
            }
            let k = (
                e.get("pid").unwrap().as_i64().unwrap(),
                e.get("tid").unwrap().as_i64().unwrap(),
                e.f64_at("ts").unwrap(),
            );
            if let Some(p) = prev {
                assert!(k >= p, "events not sorted per track: {k:?} after {p:?}");
            }
            prev = Some(k);
        }
    }

    #[test]
    fn flow_arrows_come_in_matched_pairs() {
        let j = schedule_trace_json("1f1b", &sched(ScheduleKind::OneFOneB));
        let evs = events(&j);
        let ids = |ph: &str| -> Vec<i64> {
            let mut v: Vec<i64> = evs
                .iter()
                .filter(|e| e.str_at("ph") == Some(ph))
                .map(|e| e.get("id").unwrap().as_i64().unwrap())
                .collect();
            v.sort();
            v
        };
        let (starts, ends) = (ids("s"), ids("f"));
        assert!(!starts.is_empty());
        assert_eq!(starts, ends, "every flow start needs exactly one finish");
    }

    #[test]
    fn task_counts_match_schedule_matrix() {
        for (kind, has_w) in [
            (ScheduleKind::OneFOneB, false),
            (ScheduleKind::GPipe, false),
            (ScheduleKind::Interleaved1F1B { chunks: 2 }, false),
            (ScheduleKind::ZbH1, true),
        ] {
            let s = sched(kind);
            let total = s.stages() * s.chunks * s.micro_batches();
            let j = schedule_trace_json(&kind.label(), &s);
            let evs = events(&j);
            let count = |cat: &str| evs.iter().filter(|e| e.str_at("cat") == Some(cat)).count();
            assert_eq!(count("F"), total, "{kind:?}");
            assert_eq!(count("B"), total, "{kind:?}");
            assert_eq!(count("W") > 0, has_w, "{kind:?}");
            // terminal tasks have no consumer: strictly fewer P2P slices
            // than tasks, but interior crossings are all exposed here
            assert!(count("P2P") > 0 && count("P2P") < 2 * total, "{kind:?}");
        }
    }

    #[test]
    fn spans_render_with_one_track_per_tid() {
        let spans = vec![
            SpanRecord { name: "a".into(), cat: "t", tid: 3, start_us: 1.0, dur_us: 2.0 },
            SpanRecord { name: "b".into(), cat: "t", tid: 1, start_us: 0.5, dur_us: 0.1 },
        ];
        let j = spans_to_trace_json(&spans);
        let evs = events(&j);
        let threads = evs.iter().filter(|e| e.str_at("name") == Some("thread_name")).count();
        assert_eq!(threads, 2);
        let xs: Vec<&Json> = evs.iter().filter(|e| e.str_at("ph") == Some("X")).collect();
        assert_eq!(xs.len(), 2);
        // sorted by (tid, ts): tid 1 before tid 3
        assert_eq!(xs[0].str_at("name"), Some("b"));
    }
}
