//! Lock-free per-thread span recorder.
//!
//! Design: a global `AtomicBool` gate, a per-thread buffer
//! (`thread_local`), and one mutex-protected sink that buffers are
//! flushed into only when a thread exits or [`drain`] runs. On the hot
//! path an enabled span costs two `Instant` reads and a `Vec` push into
//! thread-local storage — no locks, no cross-thread traffic; a disabled
//! span is a branch on one relaxed atomic load and returns a guard that
//! drops without doing anything.
//!
//! Worker threads spawned by `std::thread::scope` are joined before the
//! sweep returns, which runs their thread-local destructors and flushes
//! their buffers — so a [`drain`] after the sweep observes every span.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(0);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn sink() -> &'static Mutex<Vec<SpanRecord>> {
    static SINK: OnceLock<Mutex<Vec<SpanRecord>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Vec::new()))
}

/// One completed span: wall-clock µs relative to the recorder epoch.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub name: String,
    /// Trace-event category (`"phaseA"`, `"phaseB"`, `"bound"`, ...).
    pub cat: &'static str,
    /// Recorder-assigned thread id (stable per OS thread, dense from 0).
    pub tid: u64,
    pub start_us: f64,
    pub dur_us: f64,
}

struct LocalBuf {
    tid: u64,
    spans: Vec<SpanRecord>,
}

impl Drop for LocalBuf {
    fn drop(&mut self) {
        if !self.spans.is_empty() {
            if let Ok(mut sink) = sink().lock() {
                sink.append(&mut self.spans);
            }
        }
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> = RefCell::new(LocalBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        spans: Vec::new(),
    });
}

/// Turn recording on (also pins the timestamp epoch on first use).
pub fn enable() {
    epoch();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn recording off; spans already buffered stay until [`drain`].
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// RAII span: records `[construction, drop)` on the current thread when
/// recording is enabled, does nothing otherwise.
#[must_use = "a span measures until it is dropped"]
pub struct SpanGuard {
    live: Option<(String, &'static str, Instant)>,
}

/// Open a span. `cat` becomes the trace-event category.
pub fn span(name: impl Into<String>, cat: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { live: None };
    }
    SpanGuard { live: Some((name.into(), cat, Instant::now())) }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some((name, cat, t0)) = self.live.take() else { return };
        let start_us = t0.saturating_duration_since(epoch()).as_secs_f64() * 1e6;
        let dur_us = t0.elapsed().as_secs_f64() * 1e6;
        LOCAL.with(|l| {
            let mut l = l.borrow_mut();
            let tid = l.tid;
            l.spans.push(SpanRecord { name, cat, tid, start_us, dur_us });
        });
    }
}

/// Flush the calling thread's buffer, take every span recorded so far
/// (all threads), and return them ordered by (tid, start). Leaves the
/// recorder empty for the next enable/record/drain cycle.
pub fn drain() -> Vec<SpanRecord> {
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        if !l.spans.is_empty() {
            if let Ok(mut sink) = sink().lock() {
                sink.append(&mut l.spans);
            }
        }
    });
    let mut all = match sink().lock() {
        Ok(mut sink) => std::mem::take(&mut *sink),
        Err(_) => Vec::new(),
    };
    all.sort_by(|a, b| a.tid.cmp(&b.tid).then(a.start_us.total_cmp(&b.start_us)));
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    // The recorder is a process-wide singleton, so the enable/record/
    // drain cycles here are serialized under one lock to keep parallel
    // test threads from draining each other's spans mid-assert.
    fn serial() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = serial();
        disable();
        let _ = drain();
        {
            let _sp = span("ignored", "test");
        }
        assert!(drain().is_empty());
    }

    #[test]
    fn enabled_spans_are_recorded_with_nonnegative_durations() {
        let _g = serial();
        enable();
        let _ = drain();
        {
            let _sp = span("outer", "test");
            let _inner = span("inner", "test");
        }
        disable();
        let spans = drain();
        let names: Vec<&str> =
            spans.iter().map(|s| s.name.as_str()).filter(|n| *n == "outer" || *n == "inner").collect();
        assert!(names.contains(&"outer") && names.contains(&"inner"), "{names:?}");
        for s in &spans {
            assert!(s.dur_us >= 0.0 && s.start_us >= 0.0, "{s:?}");
        }
    }

    #[test]
    fn worker_thread_spans_flush_on_join() {
        let _g = serial();
        enable();
        let _ = drain();
        std::thread::scope(|scope| {
            scope.spawn(|| {
                let _sp = span("worker-span", "test");
            });
        });
        disable();
        let spans = drain();
        assert!(spans.iter().any(|s| s.name == "worker-span"), "{spans:?}");
        // drain is destructive
        assert!(drain().is_empty());
    }
}
