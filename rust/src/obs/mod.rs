//! Zero-dependency observability: Chrome trace-event JSON export
//! (Perfetto / `chrome://tracing`-loadable, written via
//! [`crate::util::json`]) plus a lock-free per-thread span recorder for
//! profiling the engine's own execution.
//!
//! Two trace sources share one output format:
//!
//! * [`trace::schedule_trace_json`] renders a *predicted* run — an
//!   executed [`crate::pipeline::Schedule`] — as per-(rank = pid,
//!   chunk = tid) duration events with F/B/W/P2P categories and
//!   send→recv flow arrows. Timestamps are deterministic model-µs, so
//!   the output is golden-testable (`tests/golden_traces.rs`).
//! * [`span::span`] + [`trace::spans_to_trace_json`] record the sweep
//!   engine's *own* wall-clock execution (phase-A prefetch, batched
//!   backend calls, per-worker phase-B compose, bound scoring, cache
//!   save/load) when `--trace-out` is passed; with recording disabled
//!   (the default) every span is a no-op and nothing is allocated.

pub mod span;
pub mod trace;

pub use span::{disable, drain, enable, enabled, span, SpanGuard, SpanRecord};
pub use trace::{schedule_trace_json, spans_to_trace_json};
