//! Simulated "real" training runs — the ground truth the predictor is
//! validated against (stand-in for the paper's GPT-NeoX jobs on
//! Perlmutter/Vista).
//!
//! A batch executes the event-accurate pipeline schedule selected by
//! [`ParallelCfg::schedule`] (1F1B, GPipe, interleaved-1F1B, or ZB-H1)
//! with per-op jittered latencies from [`ClusterSim`]. Stage compute and
//! PP P2P are kept SPLIT: each boundary crossing is sampled per (stage,
//! micro-batch, direction) and handed to the executor as a first-class
//! transfer edge (sender occupied for `1-α` of it, receiver delayed the
//! full wall-clock), so interleaved chunks pay the true `v`× crossings.
//! DP gradient sync and the optimizer/all-gather update overlap exactly
//! as Figure 2 describes: each stage starts its DP all-reduce when its
//! own gradients are complete (last backward, or last weight-grad task
//! for ZB-H1), so only the first stage's sync is exposed on the critical
//! path.

use crate::config::{ModelCfg, ParallelCfg, Platform};
use crate::ops::build::{
    dp_allgather, dp_allreduce, encoder_ops, optimizer, post_encoder_ops, pp_p2p_bwd, pp_p2p_fwd,
    pre_encoder_ops, Workload,
};
use crate::ops::params::{stage_params_exact, StageRole};
use crate::ops::{Dir, OpInstance, OpKind};
use crate::pipeline::{
    encoder_allocation, exposed_comm_us_given_exec, Executor, ScheduleError, TaskTimes,
};
use crate::sim::ClusterSim;
use crate::util::stats;

/// Everything one pipeline stage executes.
#[derive(Clone, Debug)]
pub struct StagePlan {
    pub role: StageRole,
    pub encoders: usize,
    /// COMPUTE ops run per micro-batch in each direction (pre-blocks,
    /// encoder stack with its MP syncs, post-blocks). PP P2P is no
    /// longer folded in here — see `pp_send_fwd`/`pp_send_bwd`.
    pub fwd_ops: Vec<OpInstance>,
    pub bwd_ops: Vec<OpInstance>,
    /// THIS stage's forward-direction boundary send (activations to the
    /// next stage; on the last stage this is the interleaved wrap-around
    /// hop with its own topology path). `None` when `pp == 1` (no
    /// boundary exists), which is also why `pp_p2p_us` reports 0.0 —
    /// never NaN — for single-stage pipelines.
    pub pp_send_fwd: Option<OpInstance>,
    /// THIS stage's backward-direction boundary send (input gradients to
    /// the previous stage; stage 0's entry is the backward wrap hop).
    pub pp_send_bwd: Option<OpInstance>,
    /// Exact (Table II) local parameter count.
    pub params: f64,
    pub dp_allreduce: OpInstance,
    pub dp_allgather: OpInstance,
    pub optimizer: OpInstance,
}

/// Build per-stage execution plans for a (model, parallelism, platform)
/// using exact (Table II) parameter counts — the simulator's view.
pub fn stage_plans(model: &ModelCfg, par: &ParallelCfg, platform: &Platform) -> Vec<StagePlan> {
    stage_plans_mode(model, par, platform, false)
}

/// Plan builder with selectable parameter accounting: the *predictor*
/// uses the paper's closed form (eq 6 + Table III, `paper_params =
/// true`); the simulator uses exact Table-II sums. The difference is a
/// deliberate, realistic source of modeling error (DESIGN.md §7).
pub fn stage_plans_mode(
    model: &ModelCfg,
    par: &ParallelCfg,
    platform: &Platform,
    paper_params: bool,
) -> Vec<StagePlan> {
    use crate::ops::params::stage_params_paper;
    let wl = Workload::new(model, par, platform);
    let alloc = encoder_allocation(model.encoders, par.pp);
    let mut plans = Vec::with_capacity(par.pp);
    for (s, &n_enc) in alloc.iter().enumerate() {
        let role = StageRole::of(s, par.pp);
        let mut fwd = Vec::new();
        let mut bwd = Vec::new();
        if matches!(role, StageRole::First | StageRole::Solo) {
            fwd.extend(pre_encoder_ops(model, &wl, Dir::Fwd));
            bwd.extend(pre_encoder_ops(model, &wl, Dir::Bwd));
        }
        for _ in 0..n_enc {
            fwd.extend(encoder_ops(model, &wl, Dir::Fwd));
            bwd.extend(encoder_ops(model, &wl, Dir::Bwd));
        }
        if matches!(role, StageRole::Last | StageRole::Solo) {
            fwd.extend(post_encoder_ops(model, &wl, Dir::Fwd));
            bwd.extend(post_encoder_ops(model, &wl, Dir::Bwd));
        }
        let params = if paper_params {
            stage_params_paper(role, n_enc, model.d, wl.v, par.mp)
        } else {
            stage_params_exact(role, n_enc, model.d, wl.v, par.mp)
        };
        plans.push(StagePlan {
            role,
            encoders: n_enc,
            fwd_ops: fwd,
            bwd_ops: bwd,
            // Every stage can be a sender (interleaving wraps the last
            // stage's chunk boundary back to the first), so transfer ops
            // exist on all stages whenever the pipeline has a boundary —
            // each carrying its OWN topology path (the wrap hop included).
            pp_send_fwd: (par.pp > 1).then(|| pp_p2p_fwd(&wl, s)),
            pp_send_bwd: (par.pp > 1).then(|| pp_p2p_bwd(&wl, s)),
            params,
            dp_allreduce: dp_allreduce(params, &wl),
            dp_allgather: dp_allgather(params / par.dp as f64, &wl),
            optimizer: optimizer(params, n_enc, &wl),
        });
    }
    plans
}

/// Measured components of one simulated training batch (the ground truth
/// the Table IX error analysis compares against).
#[derive(Clone, Debug, Default)]
pub struct BatchTrace {
    /// End-to-end batch time, µs.
    pub total_us: f64,
    /// Mean per-micro-batch fwd/bwd time per stage, µs.
    pub stage_fwd_us: Vec<f64>,
    pub stage_bwd_us: Vec<f64>,
    /// Mean single-encoder fwd/bwd time, µs.
    pub encoder_fwd_us: f64,
    pub encoder_bwd_us: f64,
    /// Mean single MP all-reduce invocation, µs.
    pub mp_allreduce_us: f64,
    /// Mean single PP P2P transfer, µs (0.0 — not NaN — when pp = 1 and
    /// no boundary exists).
    pub pp_p2p_us: f64,
    /// Makespan increase attributable to P2P: the schedule executed with
    /// the sampled transfer times minus the same schedule with sends
    /// zeroed (the comm-exposure column of the schedule reports), µs.
    pub p2p_exposed_us: f64,
    /// First stage's DP all-reduce (the exposed one), µs.
    pub dp_allreduce_first_us: f64,
    /// DP all-gather of the max-update stage, µs.
    pub dp_allgather_max_us: f64,
    /// Max over stages of optimizer + all-gather, µs.
    pub max_update_us: f64,
    /// Per-stage update (optimizer + all-gather) times, µs.
    pub update_us: Vec<f64>,
}

/// Execute one training batch and return the measured trace. Panics if
/// the configured pipeline schedule rejects the geometry (use
/// [`try_run_batch`] to handle that in sweeps).
pub fn run_batch(
    model: &ModelCfg,
    par: &ParallelCfg,
    platform: &Platform,
    seed: u64,
) -> BatchTrace {
    try_run_batch(model, par, platform, seed)
        .unwrap_or_else(|e| panic!("{}({}): {e}", model.name, par.label()))
}

/// Fallible batch execution: surfaces schedule-geometry and dependency
/// errors as values so a strategy sweep can skip bad combinations.
pub fn try_run_batch(
    model: &ModelCfg,
    par: &ParallelCfg,
    platform: &Platform,
    seed: u64,
) -> Result<BatchTrace, ScheduleError> {
    let plans = stage_plans(model, par, platform);
    try_run_batch_with_plans(model, par, &plans, platform, seed)
}

/// Split out so Table VIII repetitions reuse the plan construction.
/// Panics on schedule errors; see [`try_run_batch_with_plans`].
pub fn run_batch_with_plans(
    model: &ModelCfg,
    par: &ParallelCfg,
    plans: &[StagePlan],
    platform: &Platform,
    seed: u64,
) -> BatchTrace {
    try_run_batch_with_plans(model, par, plans, platform, seed)
        .unwrap_or_else(|e| panic!("{}({}): {e}", model.name, par.label()))
}

/// Fallible variant of [`run_batch_with_plans`].
pub fn try_run_batch_with_plans(
    model: &ModelCfg,
    par: &ParallelCfg,
    plans: &[StagePlan],
    platform: &Platform,
    seed: u64,
) -> Result<BatchTrace, ScheduleError> {
    try_run_batch_with_plans_exec(model, par, plans, platform, seed, &mut Executor::new())
}

/// [`try_run_batch_with_plans`] with executor buffer reuse: repeated
/// batches over the same plans (stability loops, schedule reports) hand
/// one [`Executor`] through and stop re-allocating the schedule matrices
/// for both the real run and its zero-send counterfactual.
pub fn try_run_batch_with_plans_exec(
    model: &ModelCfg,
    par: &ParallelCfg,
    plans: &[StagePlan],
    platform: &Platform,
    seed: u64,
    exec: &mut Executor,
) -> Result<BatchTrace, ScheduleError> {
    let mut sim = ClusterSim::new(platform.clone(), seed);
    // one correlated fabric state per training batch, scaled to the job's
    // node footprint (a 128-node job congests itself; a benchmark doesn't)
    sim.new_epoch_scaled(par.nodes(platform));
    let m = model.iters_per_update;
    let s_count = plans.len();

    let mut fwd = vec![vec![0.0; m]; s_count];
    let mut bwd = vec![vec![0.0; m]; s_count];
    let mut fwd_send = vec![vec![0.0; m]; s_count];
    let mut bwd_send = vec![vec![0.0; m]; s_count];
    let mut enc_fwd_samples = Vec::new();
    let mut enc_bwd_samples = Vec::new();
    let mut mp_ar_samples = Vec::new();
    let mut p2p_samples = Vec::new();
    // Only interleaved chunk walks traverse the wrap-around hops (last
    // stage's fwd send, stage 0's bwd send); for single-chunk schedules
    // those transfers never execute, so keep them out of the reported
    // pp_p2p_us mean (they can ride a different path than the interior
    // boundaries). They are still SAMPLED so the executor's send
    // matrices are complete and the jitter stream stays stable.
    let wraps = matches!(
        par.schedule,
        crate::pipeline::ScheduleKind::Interleaved1F1B { chunks } if chunks > 1
    );

    for (s, plan) in plans.iter().enumerate() {
        for i in 0..m {
            let (mut tf, mut tb) = (0.0, 0.0);
            let mut enc_sum_f = 0.0;
            let mut enc_sum_b = 0.0;
            for op in &plan.fwd_ops {
                let t = sim.sample_us(&op.lowered);
                tf += t;
                match op.kind {
                    OpKind::MpAllReduce => {
                        mp_ar_samples.push(t);
                        enc_sum_f += t;
                    }
                    OpKind::Embedding
                    | OpKind::FinalLinear
                    | OpKind::ParallelCrossEntropy => {}
                    _ if plan.encoders > 0 => enc_sum_f += t,
                    _ => {}
                }
            }
            // each boundary crossing is its own sampled transfer, no
            // longer folded into the stage's compute time
            if let Some(p2p) = &plan.pp_send_fwd {
                let t = sim.sample_us(&p2p.lowered);
                fwd_send[s][i] = t;
                if wraps || s + 1 < s_count {
                    p2p_samples.push(t);
                }
            }
            for op in &plan.bwd_ops {
                let t = sim.sample_us(&op.lowered);
                tb += t;
                match op.kind {
                    OpKind::MpAllReduce => {
                        mp_ar_samples.push(t);
                        enc_sum_b += t;
                    }
                    OpKind::Embedding
                    | OpKind::FinalLinear
                    | OpKind::ParallelCrossEntropy => {}
                    _ if plan.encoders > 0 => enc_sum_b += t,
                    _ => {}
                }
            }
            if let Some(p2p) = &plan.pp_send_bwd {
                let t = sim.sample_us(&p2p.lowered);
                bwd_send[s][i] = t;
                if wraps || s > 0 {
                    p2p_samples.push(t);
                }
            }
            fwd[s][i] = tf;
            bwd[s][i] = tb;
            if plan.encoders > 0 {
                enc_fwd_samples.push(enc_sum_f / plan.encoders as f64);
                enc_bwd_samples.push(enc_sum_b / plan.encoders as f64);
            }
        }
    }

    let times = TaskTimes::compute(fwd.clone(), bwd.clone())
        .with_sends(fwd_send, bwd_send)
        .with_overlap(par.p2p_overlap());
    let schedule = par.schedule.build();
    let sched = exec.execute(schedule.as_ref(), &times)?;
    let p2p_exposed_us =
        exposed_comm_us_given_exec(schedule.as_ref(), &times, sched.makespan(), exec)?;
    let last_bwd = sched.stage_grads_ready();
    exec.recycle(sched);

    // Figure 2 overlap: each stage's DP all-reduce starts at its own last
    // backward; the update (optimizer + all-gather) follows its sync.
    let mut total = 0.0f64;
    let mut updates = Vec::with_capacity(s_count);
    let mut dp_first = 0.0;
    let mut max_update = f64::NEG_INFINITY;
    let mut allgather_of_max = 0.0;
    for (s, plan) in plans.iter().enumerate() {
        let t_sync = sim.sample_us(&plan.dp_allreduce.lowered);
        if s == 0 {
            dp_first = t_sync;
        }
        let t_opt = sim.sample_us(&plan.optimizer.lowered);
        let t_ag = sim.sample_us(&plan.dp_allgather.lowered);
        let update = t_opt + t_ag;
        updates.push(update);
        if update > max_update {
            max_update = update;
            allgather_of_max = t_ag;
        }
        total = total.max(last_bwd[s] + t_sync + update);
    }

    Ok(BatchTrace {
        total_us: total,
        stage_fwd_us: fwd.iter().map(|v| stats::mean(v)).collect(),
        stage_bwd_us: bwd.iter().map(|v| stats::mean(v)).collect(),
        encoder_fwd_us: stats::mean(&enc_fwd_samples),
        encoder_bwd_us: stats::mean(&enc_bwd_samples),
        mp_allreduce_us: stats::mean(&mp_ar_samples),
        // mean over an empty slice is 0.0 by contract (pp = 1 has no
        // P2P samples), so this can never go NaN
        pp_p2p_us: stats::mean(&p2p_samples),
        p2p_exposed_us,
        dp_allreduce_first_us: dp_first,
        dp_allgather_max_us: allgather_of_max,
        max_update_us: max_update,
        update_us: updates,
    })
}

/// Deterministic (jitter-free) per-task times for the configured
/// pipeline: every micro-batch of a stage costs the same
/// [`crate::sim::deterministic_us`] sum over the stage's plan ops, and
/// every boundary crossing its deterministic transfer time. This is the
/// matrix `fgpm trace` executes and renders — the model's EXPECTED
/// timeline, bit-identical across runs and machines (no RNG anywhere),
/// which is what makes the trace goldens pinnable.
pub fn deterministic_task_times(
    model: &ModelCfg,
    par: &ParallelCfg,
    platform: &Platform,
) -> TaskTimes {
    let plans = stage_plans(model, par, platform);
    let m = model.iters_per_update;
    let s_count = plans.len();
    let mut fwd = vec![vec![0.0; m]; s_count];
    let mut bwd = vec![vec![0.0; m]; s_count];
    let mut fwd_send = vec![vec![0.0; m]; s_count];
    let mut bwd_send = vec![vec![0.0; m]; s_count];
    for (s, plan) in plans.iter().enumerate() {
        let det = |op: &OpInstance| crate::sim::deterministic_us(&op.lowered, platform);
        let tf: f64 = plan.fwd_ops.iter().map(det).sum();
        let tb: f64 = plan.bwd_ops.iter().map(det).sum();
        let sf = plan.pp_send_fwd.as_ref().map(det).unwrap_or(0.0);
        let sb = plan.pp_send_bwd.as_ref().map(det).unwrap_or(0.0);
        for i in 0..m {
            fwd[s][i] = tf;
            bwd[s][i] = tb;
            fwd_send[s][i] = sf;
            bwd_send[s][i] = sb;
        }
    }
    TaskTimes::compute(fwd, bwd).with_sends(fwd_send, bwd_send).with_overlap(par.p2p_overlap())
}

/// A fault-aware run: the fault-free simulated batch time plus the
/// checkpoint/restart event-loop outcome and its closed-form cross-check.
#[derive(Clone, Debug)]
pub struct FaultRun {
    /// Fault-free step (batch) seconds measured from one simulated batch.
    pub step_s: f64,
    /// Resolved per-config fault parameters (checkpoint write/restore
    /// seconds, aggregate failure rate, straggler layer).
    pub params: crate::faults::GoodputParams,
    /// The event simulation: failures roll work back to the last
    /// checkpoint and pay restore + fixed overhead + one re-warm-up step.
    pub outcome: crate::faults::SimOutcome,
    /// The optimal-checkpoint-interval-style closed form over the same
    /// parameters (property-tested against `outcome` in
    /// `tests/prop_sweep.rs`).
    pub closed_form: crate::faults::GoodputEstimate,
}

/// Execute a fault-aware training run: one simulated batch (jittered,
/// seed-deterministic) fixes the fault-free step time; the
/// [`faults`](crate::faults) event loop then plays `steps` steps through
/// failures, stragglers, and checkpoint/restart. Restart semantics: all
/// work since the last checkpoint is lost, and the job pays state
/// restore + rendezvous overhead + one re-warm-up step before making
/// progress again.
pub fn run_with_faults(
    model: &ModelCfg,
    par: &ParallelCfg,
    platform: &Platform,
    plan: &crate::faults::FaultPlan,
    steps: usize,
    seed: u64,
) -> Result<FaultRun, ScheduleError> {
    let trace = try_run_batch(model, par, platform, seed)?;
    let step_s = trace.total_us / 1e6;
    let params = crate::faults::GoodputParams::resolve(model, par, platform, plan, step_s);
    let outcome = crate::faults::simulate(&params, steps, seed);
    let closed_form = crate::faults::closed_form(&params);
    Ok(FaultRun { step_s, params, outcome, closed_form })
}

/// Table VIII statistics over `n` repeated batches.
#[derive(Clone, Debug)]
pub struct StabilityStats {
    pub min_s: f64,
    pub max_s: f64,
    pub avg_s: f64,
    /// % increase of average over minimum (the paper's variability metric).
    pub pct_increase: f64,
    pub samples_s: Vec<f64>,
}

pub fn stability(
    model: &ModelCfg,
    par: &ParallelCfg,
    platform: &Platform,
    n: usize,
    seed: u64,
) -> StabilityStats {
    let plans = stage_plans(model, par, platform);
    // one executor across all repetitions: schedule matrices are recycled
    let mut exec = Executor::new();
    let samples: Vec<f64> = (0..n)
        .map(|i| {
            try_run_batch_with_plans_exec(model, par, &plans, platform, seed + i as u64, &mut exec)
                .unwrap_or_else(|e| panic!("{}({}): {e}", model.name, par.label()))
                .total_us
                / 1e6
        })
        .collect();
    let min_s = stats::min(&samples);
    let avg_s = stats::mean(&samples);
    StabilityStats {
        min_s,
        max_s: stats::max(&samples),
        avg_s,
        pct_increase: 100.0 * (avg_s - min_s) / min_s,
        samples_s: samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::ScheduleKind;

    fn gpt_plan() -> (ModelCfg, ParallelCfg, Platform) {
        (ModelCfg::gpt20b(), ParallelCfg::new(4, 4, 8), Platform::perlmutter())
    }

    #[test]
    fn schedule_choice_threads_through_simulation() {
        // Same seed -> identical sampled task times; only the pipeline
        // discipline differs. Interleaving must strictly shrink the batch.
        let (m, par, p) = gpt_plan();
        let t_1f1b = run_batch(&m, &par, &p, 11).total_us;
        let t_gpipe = run_batch(&m, &par.with_schedule(ScheduleKind::GPipe), &p, 11).total_us;
        let t_ilv = run_batch(
            &m,
            &par.with_schedule(ScheduleKind::Interleaved1F1B { chunks: 2 }),
            &p,
            11,
        )
        .total_us;
        assert!(t_ilv < t_gpipe, "interleaved {t_ilv} vs gpipe {t_gpipe}");
        assert!(t_ilv < t_1f1b, "interleaved {t_ilv} vs 1f1b {t_1f1b}");
        // 1F1B and GPipe share the uniform-time makespan; with mild jitter
        // they stay within a few percent of each other.
        assert!(
            (t_1f1b - t_gpipe).abs() / t_1f1b < 0.05,
            "1f1b {t_1f1b} vs gpipe {t_gpipe}"
        );
    }

    #[test]
    fn try_run_batch_reports_unsupported_geometry() {
        // 6 micro-batches across 4 stages cannot interleave (6 % 4 != 0);
        // the error is a value, not a panic, so sweeps can skip it.
        let mut m = ModelCfg::llemma7b();
        m.iters_per_update = 6;
        let par = ParallelCfg::new(4, 2, 2)
            .with_schedule(ScheduleKind::Interleaved1F1B { chunks: 2 });
        let err = try_run_batch(&m, &par, &Platform::perlmutter(), 3).unwrap_err();
        assert!(matches!(err, ScheduleError::Unsupported { .. }), "{err}");
    }

    #[test]
    fn plans_cover_all_encoders() {
        let (m, par, p) = gpt_plan();
        let plans = stage_plans(&m, &par, &p);
        assert_eq!(plans.len(), 4);
        assert_eq!(plans.iter().map(|s| s.encoders).sum::<usize>(), 44);
        assert_eq!(plans[0].role, StageRole::First);
        assert_eq!(plans[3].role, StageRole::Last);
    }

    #[test]
    fn p2p_is_a_first_class_edge_not_a_stage_op() {
        let (m, par, p) = gpt_plan();
        let plans = stage_plans(&m, &par, &p);
        for (s, plan) in plans.iter().enumerate() {
            // compute op lists carry no folded transfers any more...
            assert!(!plan.fwd_ops.iter().any(|o| o.kind == OpKind::PpP2p), "stage {s}");
            assert!(!plan.bwd_ops.iter().any(|o| o.kind == OpKind::PpP2p), "stage {s}");
            // ...every stage owns BOTH boundary-transfer ops instead (the
            // interleaved wraps make even the edge stages senders)
            assert_eq!(
                plan.pp_send_fwd.as_ref().map(|o| o.kind),
                Some(OpKind::PpP2p),
                "stage {s}"
            );
            assert_eq!(
                plan.pp_send_bwd.as_ref().map(|o| o.kind),
                Some(OpKind::PpP2p),
                "stage {s}"
            );
        }
    }

    #[test]
    fn rank_map_ordering_changes_simulated_batch() {
        // Acceptance: at least one rank-map ordering shows a measurable
        // time difference for a TP-spanning-nodes placement. dp-first
        // strides the 4-wide MP group across 4 Perlmutter nodes, so every
        // encoder's MP all-reduce rides the fabric.
        use crate::net::topology::RankOrder;
        let (m, par, p) = gpt_plan();
        let tp = run_batch(&m, &par, &p, 23).total_us;
        let dpf = run_batch(&m, &par.with_rank_order(RankOrder::DpFirst), &p, 23).total_us;
        assert!(dpf > 1.2 * tp, "dp-first {dpf} vs tp-first {tp}");
    }

    #[test]
    fn single_stage_pipeline_reports_zero_p2p_not_nan() {
        // pp = 1: no boundary, no samples — the mean must be a clean 0.0.
        let mut m = ModelCfg::llemma7b();
        m.iters_per_update = 4;
        let par = ParallelCfg::new(1, 2, 2);
        let p = Platform::perlmutter();
        let plans = stage_plans(&m, &par, &p);
        assert!(plans[0].pp_send_fwd.is_none());
        assert!(plans[0].pp_send_bwd.is_none());
        let tr = run_batch(&m, &par, &p, 5);
        assert_eq!(tr.pp_p2p_us, 0.0);
        assert_eq!(tr.p2p_exposed_us, 0.0);
        assert!(tr.total_us.is_finite() && tr.total_us > 0.0);
    }

    #[test]
    fn p2p_exposure_measured_and_overlap_shrinks_batch() {
        let (m, par, p) = gpt_plan();
        let blocked = run_batch(&m, &par, &p, 11);
        assert!(blocked.p2p_exposed_us > 0.0, "{}", blocked.p2p_exposed_us);
        assert!(blocked.pp_p2p_us > 0.0);
        let overlapped = run_batch(&m, &par.with_p2p_overlap(1.0), &p, 11);
        assert!(
            overlapped.total_us < blocked.total_us,
            "overlap 1.0 {} vs 0.0 {}",
            overlapped.total_us,
            blocked.total_us
        );
    }

    #[test]
    fn zb_h1_batch_beats_1f1b() {
        // Same seed -> identical sampled times; deferring weight grads
        // off the critical path must shrink the batch.
        let (m, par, p) = gpt_plan();
        let t_1f1b = run_batch(&m, &par, &p, 17).total_us;
        let t_zb = run_batch(&m, &par.with_schedule(ScheduleKind::ZbH1), &p, 17).total_us;
        assert!(t_zb < t_1f1b, "zb-h1 {t_zb} vs 1f1b {t_1f1b}");
    }

    #[test]
    fn first_stage_has_embedding_last_has_head() {
        let (m, par, p) = gpt_plan();
        let plans = stage_plans(&m, &par, &p);
        assert!(plans[0].fwd_ops.iter().any(|o| o.kind == OpKind::Embedding));
        assert!(plans[3].fwd_ops.iter().any(|o| o.kind == OpKind::FinalLinear));
        assert!(plans[3].fwd_ops.iter().any(|o| o.kind == OpKind::ParallelCrossEntropy));
        assert!(!plans[1].fwd_ops.iter().any(|o| o.kind == OpKind::Embedding));
    }

    #[test]
    fn batch_trace_populated_and_sane() {
        let (m, par, p) = gpt_plan();
        let tr = run_batch(&m, &par, &p, 7);
        assert!(tr.total_us > 0.0);
        assert_eq!(tr.stage_fwd_us.len(), 4);
        assert!(tr.encoder_bwd_us > tr.encoder_fwd_us);
        assert!(tr.max_update_us >= tr.update_us.iter().cloned().fold(0.0, f64::max) - 1e-9);
        assert!(tr.mp_allreduce_us > 0.0 && tr.pp_p2p_us > 0.0);
        // batch must cost at least the pipeline-compute lower bound
        let compute: f64 = tr.stage_fwd_us[0] + tr.stage_bwd_us[0];
        assert!(tr.total_us > compute * m.iters_per_update as f64 * 0.5);
    }

    #[test]
    fn gpt20b_perlmutter_batch_in_expected_band() {
        // Paper Table VIII: GPT-20B(4-4-8) on Perlmutter ~ 17.4s. The
        // simulator is not calibrated to match absolutes, but must land
        // within the right order of magnitude (2-60 s).
        let (m, par, p) = gpt_plan();
        let tr = run_batch(&m, &par, &p, 1);
        let s = tr.total_us / 1e6;
        assert!((2.0..60.0).contains(&s), "batch time {s} s");
    }

    #[test]
    fn deterministic_task_times_are_reproducible_and_executable() {
        let (m, par, p) = gpt_plan();
        let a = deterministic_task_times(&m, &par, &p);
        let b = deterministic_task_times(&m, &par, &p);
        // no RNG anywhere: bit-identical across calls
        assert_eq!(a.fwd, b.fwd);
        assert_eq!(a.bwd, b.bwd);
        // per-stage times are constant across micro-batches
        for row in &a.fwd {
            for t in row {
                assert!(*t > 0.0 && t.is_finite());
                assert_eq!(*t, row[0]);
            }
        }
        // every schedule kind executes the matrix (the `fgpm trace` path)
        for kind in ScheduleKind::all(2) {
            let par = par.with_schedule(kind);
            let times = deterministic_task_times(&m, &par, &p);
            let sched = crate::pipeline::execute(par.schedule.build().as_ref(), &times).unwrap();
            assert!(sched.makespan() > 0.0, "{}", kind.label());
        }
    }

    #[test]
    fn fault_run_deterministic_and_restart_costs_show() {
        use crate::faults::{FaultPlan, FaultSpec};
        let (m, par, p) = gpt_plan();
        let mut spec = FaultSpec::production();
        // crank the GPU rate so a 200-step run sees failures for sure
        spec.mtbf_gpu_h = 20.0;
        let plan = FaultPlan::new(spec, 8);
        let a = run_with_faults(&m, &par, &p, &plan, 200, 42).unwrap();
        let b = run_with_faults(&m, &par, &p, &plan, 200, 42).unwrap();
        assert_eq!(a.outcome, b.outcome, "same seed, bit-identical fault trace");
        assert!(a.outcome.failures > 0, "failures expected at 20h/GPU MTBF");
        assert_eq!(a.outcome.committed_steps, 200);
        let g = a.outcome.goodput_frac(a.step_s);
        assert!(g > 0.0 && g < 1.0, "{g}");
        // restarts cost wall-clock the fault-free run never pays
        assert!(a.outcome.wall_s > 200.0 * a.step_s);
    }

    #[test]
    fn fault_run_off_spec_has_only_checkpoint_overhead() {
        use crate::faults::{FaultPlan, FaultSpec};
        let (m, par, p) = gpt_plan();
        let plan = FaultPlan::new(FaultSpec::off(), 4);
        let run = run_with_faults(&m, &par, &p, &plan, 40, 7).unwrap();
        assert_eq!(run.outcome.failures, 0);
        assert_eq!(run.outcome.stragglers, 0);
        assert_eq!(run.outcome.checkpoints, 10);
        // wall = useful + exactly the checkpoint writes
        let expected = 40.0 * run.step_s + 10.0 * run.params.ckpt_write_s;
        assert!((run.outcome.wall_s - expected).abs() < 1e-6, "{} vs {expected}", run.outcome.wall_s);
        assert!(run.closed_form.goodput_frac < 1.0, "write stalls still cost");
        assert!(run.closed_form.ckpt_overhead_frac > 0.0);
    }

    #[test]
    fn perlmutter_stable_vista_volatile() {
        let m = ModelCfg::gpt20b();
        let par = ParallelCfg::new(4, 8, 4);
        let sp = stability(&m, &par, &Platform::perlmutter(), 8, 42);
        let sv = stability(&m, &par, &Platform::vista(), 8, 42);
        assert!(sp.pct_increase < 5.0, "perlmutter {}%", sp.pct_increase);
        assert!(sv.pct_increase > sp.pct_increase, "vista {}%", sv.pct_increase);
    }

    #[test]
    fn stability_stats_consistent() {
        let m = ModelCfg::llemma7b();
        let par = ParallelCfg::new(4, 2, 2);
        let st = stability(&m, &par, &Platform::perlmutter(), 5, 3);
        assert!(st.min_s <= st.avg_s && st.avg_s <= st.max_s);
        assert!(st.pct_increase >= 0.0);
        assert_eq!(st.samples_s.len(), 5);
    }
}
