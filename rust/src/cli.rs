//! `fgpm` command-line interface: every paper experiment is a subcommand.
//!
//! Pipeline commands: `collect` -> `train` -> `predict`/`table9`/`serve`.
//! Self-contained report commands (`table8`, `fig2`, `fig3`, `ablate`)
//! run their whole pipeline in-process.


use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::predictor::opcache::{self, OpPredictionCache};

use crate::baselines::{Analytical, LogLinear};
use crate::config::{ArrivalKind, ModelCfg, ParallelCfg, Platform, ServingLoad, TopoSpec, WorkloadKind};
use crate::coordinator::server;
use crate::net::topology::RankOrder;
use crate::pipeline::ScheduleKind;
use crate::coordinator::{BatcherCfg, PredictionService};
use crate::forest::persist::{load_registry, save_registry};
use crate::predictor::registry::BatchPredictor;
use crate::predictor::{predict, Registry};
use crate::report::{self, fig2_markdown, fig3_markdown, table8_markdown, table9_markdown};
use crate::runtime::{artifacts_dir, Engine, XlaForestPredictor};
use crate::sampling::collector::{collect_platform, load_datasets, save_datasets};
use crate::util::cli::Spec;
use crate::util::stats;

const USAGE: &str = "\
fgpm — fine-grained GPU performance modeling for distributed LLM training

usage: fgpm <command> [options]

commands:
  models       print the target model configurations (Table IV)
  platforms    print the simulated cluster specs (Table V)
  collect      run the micro-benchmark sampling plans (Tables VI-VII)
  train        fit + select per-operator regressors (80/20 validation)
  predict      predict one (model, parallel, platform) configuration
               (add --explain for the per-op cost ledger, --trace-out for
               an engine execution trace)
  explain      decompose one configuration's predicted step into the
               op-class x direction x network-tier cost ledger
  trace        render the predicted pipeline schedule as Chrome
               trace-event JSON (load in Perfetto / chrome://tracing)
  sweep        rank all parallelism strategies for a model at a GPU count
               (add --remote host:port to run it on a served coordinator;
               add --faults spec for goodput / useful-FLOP columns;
               add --trace-out for an engine execution trace)
  serve-plan   rank (tp x replicas, max-batch) INFERENCE deployments of a
               model against a QPS target and a p99 token-latency SLO
               (prefill/decode priced through the same op cache as sweeps)
  goodput      checkpoint-interval x MTBF goodput grid for one config
               (closed-form Daly/Young estimate + event-sim cross-check)
  topo         print the cluster tiers + group->tier traffic matrix for a config
  schedules    compare pipeline schedules (1F1B / GPipe / interleaved / ZB-H1) for one config
  table8       reproduce Table VIII (performance stability)
  table9       reproduce Table IX  (component-level prediction errors)
  fig2         reproduce Figure 2  (pipeline timelines, ASCII)
  fig3         reproduce Figure 3  (component time proportions)
  ablate       compare regressors vs analytical/linear baselines
  serve        run the JSON-lines TCP prediction service (predict/stats/ping
               + whole sweeps streamed over TCP, disk-persistent op cache)
  e2e          full pipeline: collect -> train -> validate both platforms

run `fgpm <command> --help` for options.";

pub fn run(argv: &[String]) -> Result<i32> {
    let Some(cmd) = argv.first() else {
        println!("{USAGE}");
        return Ok(2);
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "models" => cmd_models(),
        "platforms" => cmd_platforms(),
        "collect" => cmd_collect(rest),
        "train" => cmd_train(rest),
        "predict" => cmd_predict(rest),
        "explain" => cmd_explain(rest),
        "trace" => cmd_trace(rest),
        "sweep" => cmd_sweep(rest),
        "serve-plan" => cmd_serve_plan(rest),
        "goodput" => cmd_goodput(rest),
        "topo" => cmd_topo(rest),
        "schedules" => cmd_schedules(rest),
        "table8" => cmd_table8(rest),
        "table9" => cmd_table9(rest),
        "fig2" => cmd_fig2(rest),
        "fig3" => cmd_fig3(rest),
        "ablate" => cmd_ablate(rest),
        "serve" => cmd_serve(rest),
        "e2e" => cmd_e2e(rest),
        "--help" | "-h" | "help" => {
            println!("{USAGE}");
            Ok(0)
        }
        other => Err(anyhow!("unknown command '{other}'\n\n{USAGE}")),
    }
}

fn parse_or_help(spec: &Spec, argv: &[String]) -> Result<Option<crate::util::cli::Args>> {
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        println!("{}", spec.help_text());
        return Ok(None);
    }
    Ok(Some(spec.parse(argv)?))
}

fn platform_arg(args: &crate::util::cli::Args) -> Result<Platform> {
    Platform::by_name(&args.str("platform"))
        .with_context(|| format!("unknown platform '{}'", args.str("platform")))
}

fn model_arg(args: &crate::util::cli::Args) -> Result<ModelCfg> {
    ModelCfg::by_name(&args.str("model"))
        .with_context(|| format!("unknown model '{}'", args.str("model")))
}

/// Apply `--schedule` to a parsed `ParallelCfg`. A typed `--schedule`
/// wins over the default; typing BOTH `--schedule` and a contradictory
/// `--parallel pp-mp-dp/<schedule>` suffix is rejected rather than
/// silently resolved.
fn apply_schedule_arg(args: &crate::util::cli::Args, par: ParallelCfg) -> Result<ParallelCfg> {
    let s = args.str("schedule");
    let kind = ScheduleKind::parse(&s).with_context(|| {
        format!("unknown schedule '{s}' (expected 1f1b|gpipe|interleaved[:v]|zb-h1)")
    })?;
    if !args.is_explicit("schedule") {
        return Ok(par); // keep whatever --parallel carried (default: 1f1b)
    }
    anyhow::ensure!(
        par.schedule == ScheduleKind::OneFOneB || par.schedule == kind,
        "--schedule {} contradicts --parallel suffix /{}; pass one or the other",
        kind.label(),
        par.schedule.label()
    );
    Ok(par.with_schedule(kind))
}

/// Apply `--p2p-overlap` (fraction of each PP transfer overlapped with
/// the endpoints' compute) to a parsed `ParallelCfg`.
fn apply_overlap_arg(args: &crate::util::cli::Args, par: ParallelCfg) -> Result<ParallelCfg> {
    let alpha = args.f64("p2p-overlap")?;
    anyhow::ensure!(
        (0.0..=1.0).contains(&alpha),
        "--p2p-overlap must be in [0, 1], got {alpha}"
    );
    Ok(par.with_p2p_overlap(alpha))
}

/// Apply `--rank-map` (how the pp/dp/mp cube is placed onto GPUs) to a
/// parsed `ParallelCfg`. An explicit flag wins; a contradicting
/// NON-DEFAULT `--parallel ...@<order>` suffix is rejected. (As with
/// `--schedule` vs the `/<schedule>` suffix, an explicit default suffix
/// — `@tp-first` — is indistinguishable from no suffix and yields to
/// the flag.)
fn apply_rank_map_arg(args: &crate::util::cli::Args, par: ParallelCfg) -> Result<ParallelCfg> {
    let s = args.str("rank-map");
    let order = RankOrder::parse(&s)
        .with_context(|| format!("unknown rank map '{s}' (expected tp-first|dp-first|pp-first)"))?;
    if !args.is_explicit("rank-map") {
        return Ok(par); // keep whatever --parallel carried (default: tp-first)
    }
    anyhow::ensure!(
        par.rank_order == RankOrder::TpFirst || par.rank_order == order,
        "--rank-map {} contradicts --parallel suffix @{}; pass one or the other",
        order.label(),
        par.rank_order.label()
    );
    Ok(par.with_rank_order(order))
}

/// Apply `--topo` (fabric shape above the node tier) to a platform.
fn apply_topo_arg(args: &crate::util::cli::Args, platform: Platform) -> Result<Platform> {
    let s = args.str("topo");
    let spec = TopoSpec::parse(&s).with_context(|| {
        format!("unknown topology '{s}' (expected flat | rail:<nodes_per_rail>[:<spine_bw_frac>])")
    })?;
    Ok(platform.with_topo(spec))
}

/// The flag cluster every configuration-shaped command shares
/// (predict/explain/trace/sweep). Declared once so the commands cannot
/// drift apart on names or defaults — the unit test below pins the set.
const CONFIG_FLAG_NAMES: [&str; 5] = ["schedule", "p2p-overlap", "rank-map", "topo", "cache-dir"];

/// Append the shared configuration flag cluster to a command spec.
/// `sweep_variants` switches the `--schedule`/`--rank-map` help to the
/// sweep's cross-product spelling (those two additionally accept `all`);
/// names and defaults are identical either way.
fn with_config_flags(spec: Spec, sweep_variants: bool) -> Spec {
    spec.opt(
        "schedule",
        "1f1b",
        if sweep_variants {
            "pipeline schedule (1f1b|gpipe|interleaved[:v]|zb-h1|all)"
        } else {
            "pipeline schedule (1f1b|gpipe|interleaved[:v]|zb-h1)"
        },
    )
    .opt("p2p-overlap", "0", "fraction of PP P2P overlapped with compute [0,1]")
    .opt(
        "rank-map",
        "tp-first",
        if sweep_variants {
            "rank placement (tp-first|dp-first|pp-first|all)"
        } else {
            "rank placement (tp-first|dp-first|pp-first)"
        },
    )
    .opt("topo", "flat", "fabric shape (flat | rail:<nodes_per_rail>[:<spine_bw_frac>])")
    .opt("cache-dir", "", "disk-persist the op-prediction cache in this directory")
}

/// Parse + apply the shared cluster in one place: `--schedule`,
/// `--p2p-overlap`, and `--rank-map` onto the parallel config, `--topo`
/// onto the platform. (The sweep keeps its own schedule/rank-map parse —
/// it crosses `all` — but shares the spec declaration above.)
fn apply_config_args(
    args: &crate::util::cli::Args,
    par: ParallelCfg,
    platform: Platform,
) -> Result<(ParallelCfg, Platform)> {
    let par = apply_rank_map_arg(args, apply_overlap_arg(args, apply_schedule_arg(args, par)?)?)?;
    Ok((par, apply_topo_arg(args, platform)?))
}

/// Reject (model, parallel) combinations the schedule cannot run.
fn validate_schedule(model: &ModelCfg, par: &ParallelCfg) -> Result<()> {
    par.validate_schedule(model.iters_per_update).map_err(|e| anyhow!("{e}"))
}

/// Parse `--faults off|spec` (+ its satellite knobs) into the sweep
/// spec's optional fault plan. `off` is the exact fault-free path —
/// every existing output stays bit-identical — and rejects
/// explicitly-typed fault knobs rather than silently ignoring them.
fn faults_arg(args: &crate::util::cli::Args) -> Result<Option<crate::faults::FaultPlan>> {
    let mode = args.str("faults");
    match mode.as_str() {
        "off" => {
            for opt in ["mtbf-gpu-h", "ckpt-interval"] {
                anyhow::ensure!(
                    !args.is_explicit(opt),
                    "--{opt} has no effect with --faults off (pass --faults spec)"
                );
            }
            Ok(None)
        }
        "spec" => {
            let mut fs = crate::faults::FaultSpec::production();
            let mtbf = args.f64("mtbf-gpu-h")?;
            anyhow::ensure!(
                mtbf.is_finite() && mtbf > 0.0,
                "--mtbf-gpu-h must be a positive number of hours, got {mtbf}"
            );
            fs.mtbf_gpu_h = mtbf;
            let interval = args.usize("ckpt-interval")?;
            anyhow::ensure!(interval >= 1, "--ckpt-interval must be >= 1 step");
            Ok(Some(crate::faults::FaultPlan::new(fs, interval)))
        }
        other => Err(anyhow!("--faults expects off|spec, got '{other}'")),
    }
}

/// Parse a comma-separated numeric list option with a per-item check.
fn list_arg<T>(
    args: &crate::util::cli::Args,
    name: &str,
    parse: impl Fn(&str) -> Option<T>,
) -> Result<Vec<T>> {
    args.str(name)
        .split(',')
        .map(|s| {
            parse(s.trim())
                .ok_or_else(|| anyhow!("--{name}: bad list entry '{}'", s.trim()))
        })
        .collect()
}

fn cmd_models() -> Result<i32> {
    for m in ModelCfg::all() {
        println!(
            "{:<10} d={} l={} h={} encoders={} micro_batch={} iters/update={} \
             fused_softmax={} flash={} norm={:?} (~{:.1}B params)",
            m.name,
            m.d,
            m.l,
            m.h,
            m.encoders,
            m.micro_batch,
            m.iters_per_update,
            m.fused_softmax,
            m.flash_attention,
            m.norm,
            m.approx_params() / 1e9
        );
    }
    Ok(0)
}

fn cmd_platforms() -> Result<i32> {
    for p in Platform::all() {
        println!(
            "{:<11} gpu={} ({} TFLOPs fp16, {} GB/s HBM) {} GPUs/node x {} nodes, \
             intra {} GB/s, inter {} GB/s",
            p.name,
            p.gpu.name,
            p.gpu.peak_tflops_fp16,
            p.gpu.mem_bw_gbs,
            p.gpus_per_node,
            p.max_nodes,
            p.intra_bw_gbs,
            p.inter_bw_gbs
        );
    }
    Ok(0)
}

fn cmd_collect(argv: &[String]) -> Result<i32> {
    let spec = Spec::new("collect", "run the Table VI/VII micro-benchmark sampling plans")
        .opt("platform", "perlmutter", "target platform (perlmutter|vista)")
        .opt("out", "datasets", "output directory")
        .opt("seed", "42", "rng seed");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let platform = platform_arg(&args)?;
    let seed = args.u64("seed")?;
    let t0 = std::time::Instant::now();
    let data = collect_platform(&platform, seed);
    let rows: usize = data.values().map(|d| d.len()).sum();
    save_datasets(&data, &platform, Path::new(&args.str("out")))?;
    println!(
        "collected {} datasets / {} rows for {} in {:?} -> {}/{}/",
        data.len(),
        rows,
        platform.name,
        t0.elapsed(),
        args.str("out"),
        platform.name
    );
    Ok(0)
}

fn cmd_train(argv: &[String]) -> Result<i32> {
    let spec = Spec::new("train", "fit + select per-operator regressors (80/20 validation)")
        .opt("platform", "perlmutter", "target platform")
        .opt("datasets", "datasets", "dataset directory from `collect`")
        .opt("out", "forests", "output directory for trained registries")
        .opt("seed", "7", "rng seed");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let platform = platform_arg(&args)?;
    let data = load_datasets(&platform, Path::new(&args.str("datasets")))
        .context("loading datasets (run `fgpm collect` first)")?;
    anyhow::ensure!(!data.is_empty(), "no datasets found");
    let t0 = std::time::Instant::now();
    let reg = Registry::train(platform.name, &data, args.u64("seed")?);
    let path = PathBuf::from(args.str("out")).join(format!("{}.json", platform.name));
    save_registry(platform.name, &reg.forests, &path)?;
    println!(
        "trained {} regressors for {} in {:?} (mean val MAPE {:.2}%) -> {path:?}",
        reg.forests.len(),
        platform.name,
        t0.elapsed(),
        reg.mean_val_mape()
    );
    Ok(0)
}

/// Load a registry file if present; otherwise collect + train in-process.
/// Also returns the registry-content hash (file bytes when loaded from
/// disk, canonical JSON when freshly trained) — one ingredient of the
/// disk op-cache fingerprint.
fn registry_for(platform: &Platform, forests_dir: &str, seed: u64) -> Result<(Registry, u64)> {
    let path = PathBuf::from(forests_dir).join(format!("{}.json", platform.name));
    if path.exists() {
        if platform.topo != TopoSpec::Flat {
            eprintln!(
                "[fgpm] note: --topo {} changes the sampled fabric; a registry collected \
                 under a different topology will not reflect it (delete {path:?} or re-run \
                 `fgpm collect` to retrain)",
                platform.topo.label()
            );
        }
        let bytes = std::fs::read(&path)?;
        let hash = opcache::fnv1a64(&bytes);
        let (name, forests) = load_registry(&path)?;
        anyhow::ensure!(name == platform.name, "registry platform mismatch");
        return Ok((Registry::from_forests(name, forests), hash));
    }
    eprintln!("[fgpm] no registry at {path:?}; collecting + training in-process...");
    let data = collect_platform(platform, seed);
    let reg = Registry::train(platform.name, &data, seed);
    let _ = save_registry(platform.name, &reg.forests, &path);
    let hash = opcache::fnv1a64(
        crate::forest::persist::registry_to_json(platform.name, &reg.forests)
            .to_string()
            .as_bytes(),
    );
    Ok((reg, hash))
}

/// Fingerprint keying the `--cache-dir` disk op cache: a cached
/// prediction is only reusable while the trained sampling registry, the
/// platform spec (incl. `--topo`), the inference backend flavor, and the
/// workload FAMILY all match what produced it. The training family keeps
/// the historical 3-part hash — existing cache files stay warm across the
/// workload-aware upgrade — while any other family (serving) appends its
/// label as a 4th part and lands in its own file (see PROTOCOL.md).
fn cache_fingerprint_for(
    registry_hash: u64,
    platform: &Platform,
    xla: bool,
    workload: &WorkloadKind,
) -> u64 {
    let mut parts = vec![
        registry_hash,
        opcache::fnv1a64(format!("{platform:?}").as_bytes()),
        opcache::fnv1a64(if xla { "xla" } else { "native" }.as_bytes()),
    ];
    if workload.family() != "training" {
        parts.push(opcache::fnv1a64(workload.family().as_bytes()));
    }
    opcache::combine_hashes(&parts)
}

/// The training-family fingerprint every historical caller uses.
fn cache_fingerprint(registry_hash: u64, platform: &Platform, xla: bool) -> u64 {
    cache_fingerprint_for(registry_hash, platform, xla, &WorkloadKind::training())
}

/// Where the disk op cache lives under `--cache-dir`. The fingerprint
/// is part of the FILE NAME (not just the header): runs differing in
/// topology, registry, or backend each keep their own warm file instead
/// of alternately clobbering a shared one into permanent cold starts.
fn op_cache_path(cache_dir: &str, platform: &Platform, fingerprint: u64) -> PathBuf {
    Path::new(cache_dir).join(format!("opcache_{}_{fingerprint:016x}.bin", platform.name))
}

/// The tier-split cache line printed by predict/sweep after a cached run.
fn cache_stats_line(s: &crate::predictor::opcache::CacheStats) -> String {
    format!(
        "op-cache hit-rate {:.0}% [mem {:.0}% / disk {:.0}%], {} distinct ops",
        s.hit_rate() * 100.0,
        s.memory_hit_rate() * 100.0,
        s.disk_hit_rate() * 100.0,
        s.entries
    )
}

/// Wrap a registry in the requested inference backend (current thread —
/// the XLA engine is not Send; `cmd_serve` builds it on the executor
/// thread via a factory instead).
fn backend_for(reg: Registry, use_xla: bool) -> Result<Box<dyn BatchPredictor>> {
    if use_xla {
        let engine = Engine::load(&artifacts_dir())?;
        let flat = reg.export_flat(engine.manifest.trees, engine.manifest.nodes);
        Ok(Box::new(XlaForestPredictor::new(engine, &flat)?))
    } else {
        Ok(Box::new(reg))
    }
}

fn cmd_predict(argv: &[String]) -> Result<i32> {
    let spec = Spec::new("predict", "predict one configuration's batch time + components")
        .opt("model", "gpt20b", "model preset")
        .opt("parallel", "4-4-8", "pp-mp-dp[/schedule][@rank-map]")
        .opt("platform", "perlmutter", "target platform");
    let spec = with_config_flags(spec, false)
        .opt("forests", "forests", "trained registry directory")
        .opt("trace-out", "", "write the engine's own execution trace (Chrome JSON) to this file")
        .opt("seed", "7", "rng seed")
        .flag("explain", "append the per-op cost attribution ledger to the output")
        .flag("xla", "serve inference from the AOT Pallas executable (PJRT)");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let model = model_arg(&args)?;
    let par = ParallelCfg::parse(&args.str("parallel"))
        .context("bad --parallel (expected pp-mp-dp[/schedule][@rank-map])")?;
    let (par, platform) = apply_config_args(&args, par, platform_arg(&args)?)?;
    validate_schedule(&model, &par)?;
    anyhow::ensure!(par.fits(&platform), "{} needs {} GPUs", par.label(), par.gpus());
    let (reg, reg_hash) = registry_for(&platform, &args.str("forests"), args.u64("seed")?)?;
    let use_xla = args.has_flag("xla");
    let mut backend = backend_for(reg, use_xla)?;
    let cache_dir = args.str("cache-dir");
    let explain = args.has_flag("explain");
    let trace_out = args.str("trace-out");
    if !trace_out.is_empty() {
        crate::obs::enable();
    }
    let mut ledger = None;
    let cp = if cache_dir.is_empty() && !explain {
        // the exact default path: no cache indirection at all
        let _g = crate::obs::span(format!("predict {}", par.label()), "predict");
        predict(&model, &par, &platform, backend.as_mut())
    } else {
        // --explain and --cache-dir both route through a shared op cache,
        // so the ledger decomposes the SAME predictions the step time was
        // composed from (no second round of backend calls)
        let fp = cache_fingerprint(reg_hash, &platform, use_xla);
        let persist = (!cache_dir.is_empty()).then(|| op_cache_path(&cache_dir, &platform, fp));
        let cache = OpPredictionCache::new();
        if let Some(path) = &persist {
            let _g = crate::obs::span("op-cache load", "cache");
            eprintln!("[fgpm] op cache {path:?}: {}", cache.load(path, fp).describe());
        }
        let cp = {
            let _g = crate::obs::span(format!("predict {}", par.label()), "predict");
            crate::predictor::e2e::predict_with_cache(
                &model,
                &par,
                &platform,
                backend.as_mut(),
                &cache,
            )
        };
        if explain {
            ledger = Some(crate::predictor::e2e::explain_with_cache(
                &model,
                &par,
                &platform,
                backend.as_mut(),
                &cache,
            ));
        }
        if let Some(path) = &persist {
            let _g = crate::obs::span("op-cache save", "cache");
            if let Err(e) = cache.save(path, fp) {
                eprintln!("[fgpm] WARNING: could not save op cache {path:?}: {e}");
            }
        }
        eprintln!("[fgpm] {}", cache_stats_line(&cache.stats()));
        cp
    };
    if !trace_out.is_empty() {
        crate::obs::disable();
        let spans = crate::obs::drain();
        std::fs::write(&trace_out, crate::obs::spans_to_trace_json(&spans).to_string())
            .with_context(|| format!("writing --trace-out {trace_out}"))?;
        eprintln!("[fgpm] wrote {} engine spans -> {trace_out}", spans.len());
    }
    println!("{}", server::prediction_to_json(&cp));
    if let Some(l) = &ledger {
        println!("\n{}", crate::report::tables::explain_table_text(l));
    }
    println!("\npredicted batch time: {:.2} s", cp.total_us / 1e6);
    Ok(0)
}

/// `fgpm explain`: the per-op cost attribution ledger on its own —
/// `predict --explain` without the prediction JSON.
fn cmd_explain(argv: &[String]) -> Result<i32> {
    let spec = Spec::new(
        "explain",
        "decompose one configuration's predicted step into the op-class x \
         direction x network-tier cost ledger (rows reconstruct the step \
         time exactly; the closed forms are linear in their components)",
    )
    .opt("model", "gpt20b", "model preset")
    .opt("parallel", "4-4-8", "pp-mp-dp[/schedule][@rank-map]")
    .opt("platform", "perlmutter", "target platform");
    let spec = with_config_flags(spec, false)
        .opt("forests", "forests", "trained registry directory")
        .opt("seed", "7", "rng seed")
        .flag("xla", "serve inference from the AOT Pallas executable (PJRT)");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let model = model_arg(&args)?;
    let par = ParallelCfg::parse(&args.str("parallel"))
        .context("bad --parallel (expected pp-mp-dp[/schedule][@rank-map])")?;
    let (par, platform) = apply_config_args(&args, par, platform_arg(&args)?)?;
    validate_schedule(&model, &par)?;
    anyhow::ensure!(par.fits(&platform), "{} needs {} GPUs", par.label(), par.gpus());
    let (reg, reg_hash) = registry_for(&platform, &args.str("forests"), args.u64("seed")?)?;
    let use_xla = args.has_flag("xla");
    let mut backend = backend_for(reg, use_xla)?;
    let cache_dir = args.str("cache-dir");
    let cache = OpPredictionCache::new();
    let persist = if cache_dir.is_empty() {
        None
    } else {
        let fp = cache_fingerprint(reg_hash, &platform, use_xla);
        let path = op_cache_path(&cache_dir, &platform, fp);
        eprintln!("[fgpm] op cache {path:?}: {}", cache.load(&path, fp).describe());
        Some((path, fp))
    };
    let ledger = crate::predictor::e2e::explain_with_cache(
        &model,
        &par,
        &platform,
        backend.as_mut(),
        &cache,
    );
    if let Some((path, fp)) = persist {
        if let Err(e) = cache.save(&path, fp) {
            eprintln!("[fgpm] WARNING: could not save op cache {path:?}: {e}");
        }
    }
    print!("{}", crate::report::tables::explain_table_text(&ledger));
    Ok(0)
}

/// `fgpm trace`: render the predicted pipeline schedule as Chrome
/// trace-event JSON. Deterministic — task times come from the
/// closed-form operator model ([`crate::trainrun::deterministic_task_times`]),
/// not a sampled run, so the same spec always produces the same bytes
/// (the property the golden-trace tests pin).
fn cmd_trace(argv: &[String]) -> Result<i32> {
    let spec = Spec::new(
        "trace",
        "render the predicted pipeline schedule as Chrome trace-event JSON \
         (open in Perfetto or chrome://tracing; ranks are processes, \
         virtual-stage chunks are threads, flow arrows mark P2P crossings)",
    )
    .opt("model", "gpt20b", "model preset")
    .opt("parallel", "4-4-8", "pp-mp-dp[/schedule][@rank-map]")
    .opt("platform", "perlmutter", "target platform");
    let spec = with_config_flags(spec, false).opt("out", "trace.json", "output file");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    // the trace is closed-form — there is no predictor, hence no op cache
    anyhow::ensure!(
        !args.is_explicit("cache-dir"),
        "--cache-dir has no effect on trace (the schedule render calls no predictor)"
    );
    let model = model_arg(&args)?;
    let par = ParallelCfg::parse(&args.str("parallel"))
        .context("bad --parallel (expected pp-mp-dp[/schedule][@rank-map])")?;
    let (par, platform) = apply_config_args(&args, par, platform_arg(&args)?)?;
    validate_schedule(&model, &par)?;
    anyhow::ensure!(par.fits(&platform), "{} needs {} GPUs", par.label(), par.gpus());
    let times = crate::trainrun::deterministic_task_times(&model, &par, &platform);
    let sched = crate::pipeline::execute(par.schedule.build().as_ref(), &times)
        .map_err(|e| anyhow!("{e}"))?;
    let label = format!(
        "{} {} on {} ({})",
        model.name,
        par.label(),
        platform.name,
        par.schedule.label()
    );
    let j = crate::obs::schedule_trace_json(&label, &sched);
    let out = args.str("out");
    let events = j.get("traceEvents").and_then(|a| a.as_arr().map(|v| v.len())).unwrap_or(0);
    std::fs::write(&out, j.to_string()).with_context(|| format!("writing --out {out}"))?;
    println!(
        "wrote {events} trace events ({} ranks x {} micro-batches, makespan {:.2} ms) -> {out}",
        sched.stages(),
        sched.micro_batches(),
        sched.makespan() / 1e3
    );
    Ok(0)
}

fn cmd_sweep(argv: &[String]) -> Result<i32> {
    let spec = Spec::new("sweep", "rank all pp-mp-dp strategies for a model at a GPU count")
        .opt("model", "gpt20b", "model preset")
        .opt("platform", "perlmutter", "target platform")
        .opt("gpus", "128", "total GPUs");
    let spec = with_config_flags(spec, true)
        .opt("global-batch", "0", "override sequences per parameter update (0 = model preset)")
        .opt("top-k", "0", "return only the k fastest configs, branch-and-bound pruning the rest (0 = full table)")
        .flag("no-prune", "with --top-k: evaluate every config anyway (disable the analytical bound)")
        .opt("faults", "off", "fault model for goodput columns (off | spec = production rates)")
        .opt("mtbf-gpu-h", "40000", "with --faults spec: per-GPU mean time between failures, hours")
        .opt("ckpt-interval", "64", "with --faults spec: checkpoint every N steps")
        .opt("jobs", "0", "evaluation worker threads (0 = one per core)")
        .opt("remote", "", "run the sweep on a coordinator at host:port instead of locally")
        .opt("retries", "2", "with --remote: reconnect-and-resume attempts after a dropped stream")
        .opt("backoff-ms", "100", "with --remote: base retry backoff (capped exponential, jittered)")
        .opt("cache-max-mb", "0", "cap the persisted op-cache file, LRU-evicting (0 = unlimited)")
        .opt("trace-out", "", "write the engine's own execution trace (Chrome JSON) to this file")
        .opt("forests", "forests", "trained registry directory")
        .opt("seed", "7", "rng seed")
        .flag("xla", "use the AOT Pallas executable");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let platform = apply_topo_arg(&args, platform_arg(&args)?)?;
    let model = model_arg(&args)?;
    let gpus = args.usize("gpus")?;
    let sched_str = args.str("schedule");
    let kinds: Vec<ScheduleKind> = if sched_str == "all" {
        ScheduleKind::all(2)
    } else {
        vec![ScheduleKind::parse(&sched_str)
            .with_context(|| format!("unknown schedule '{sched_str}'"))?]
    };
    // `--rank-map all` crosses placements the way `--schedule all`
    // crosses schedules
    let rank_str = args.str("rank-map");
    let orders: Vec<RankOrder> = if rank_str == "all" {
        RankOrder::all()
    } else {
        vec![RankOrder::parse(&rank_str)
            .with_context(|| format!("unknown rank map '{rank_str}' (expected tp-first|dp-first|pp-first|all)"))?]
    };
    // parse + range-check the constant overlap once, before enumerating
    let overlap = apply_overlap_arg(&args, ParallelCfg::new(1, 1, 1))?.p2p_overlap();
    let top_k = args.usize("top-k")?;
    let faults = faults_arg(&args)?;
    let global_batch = args.usize("global-batch")?;
    let workload = match global_batch {
        0 => WorkloadKind::training(),
        g => WorkloadKind::Training { global_batch: Some(g) },
    };
    let sweep_spec = crate::sweep::SweepSpec {
        gpus,
        max_pp: 16,
        max_mp: 16,
        schedules: kinds,
        rank_orders: orders,
        p2p_overlap: overlap,
        top_k: (top_k > 0).then_some(top_k),
        prune: !args.has_flag("no-prune"),
        faults,
        workload,
    };
    let title = format!(
        "{} on {} with {} GPUs — predicted batch seconds:",
        model.name, platform.name, gpus
    );

    let remote = args.str("remote");
    if !remote.is_empty() {
        // local-only knobs have no effect on a remote coordinator (it
        // chose its backend, cache, and worker count at startup); reject
        // explicitly-typed ones instead of silently ignoring them
        for opt in ["cache-dir", "cache-max-mb", "forests", "jobs", "seed", "trace-out"] {
            anyhow::ensure!(
                !args.is_explicit(opt),
                "--{opt} has no effect with --remote (the coordinator's own settings apply)"
            );
        }
        anyhow::ensure!(
            !args.has_flag("xla"),
            "--xla has no effect with --remote (the coordinator chose its backend at startup)"
        );
        // thin client: the coordinator runs the sweep on ITS persistent
        // cache; we only re-render the streamed rows (same table code as
        // the local path — byte-identical output, property-tested)
        let request = server::sweep_request_json(
            &args.str("model"),
            &args.str("platform"),
            &platform.topo,
            &sweep_spec,
        );
        // a dropped stream resumes from the last row received; jitter is
        // seeded from the request bytes so a given invocation's backoff
        // schedule replays exactly
        let retry_cfg = server::RetryCfg {
            retries: args.u64("retries")? as u32,
            backoff: std::time::Duration::from_millis(args.u64("backoff-ms")?.max(1)),
            seed: crate::predictor::opcache::fnv1a64(request.to_string().as_bytes()),
        };
        let rs = server::remote_sweep_resilient(&remote, &request, &retry_cfg)
            .map_err(|e| anyhow!("{e}"))?;
        let skipped_oom = rs.summary.usize_at("skipped_oom").unwrap_or(0);
        let skipped_sched = rs.summary.usize_at("skipped_sched").unwrap_or(0);
        let skipped_microbatch = rs.summary.usize_at("skipped_microbatch").unwrap_or(0);
        if sweep_spec.faults.is_some() {
            let rows: Vec<(String, f64, f64, f64, f64, f64)> = rs
                .rows
                .iter()
                .map(|r| {
                    let (g, u, c) = r.goodput.unwrap_or((0.0, 0.0, 0.0));
                    (r.label.clone(), r.total_us / 1e6, r.mem_gib, g, u, c)
                })
                .collect();
            print!(
                "{}",
                crate::report::tables::goodput_sweep_table_text(
                    &title,
                    &rows,
                    skipped_oom,
                    skipped_sched,
                    skipped_microbatch,
                    platform.gpu.hbm_gib
                )
            );
        } else {
            let rows: Vec<(String, f64, f64)> = rs
                .rows
                .iter()
                .map(|r| (r.label.clone(), r.total_us / 1e6, r.mem_gib))
                .collect();
            print!(
                "{}",
                crate::report::tables::sweep_table_text(
                    &title,
                    &rows,
                    skipped_oom,
                    skipped_sched,
                    skipped_microbatch,
                    platform.gpu.hbm_gib
                )
            );
        }
        let remote_pruned = rs.summary.usize_at("pruned").unwrap_or(0);
        let prune_note = if remote_pruned > 0 {
            format!(
                ", pruned {remote_pruned} configs via bound ({:.0}%)",
                rs.summary.f64_at("pruned_frac").unwrap_or(0.0) * 100.0
            )
        } else {
            String::new()
        };
        let goodput_note = match rs.summary.f64_at("best_goodput_frac") {
            Some(g) => format!(
                ", best goodput {:.1}% (useful FLOPs {:.1}%)",
                g * 100.0,
                rs.summary.f64_at("best_useful_flop_frac").unwrap_or(0.0) * 100.0
            ),
            None => String::new(),
        };
        println!(
            "evaluated {} configs in {:.0?} on {remote} ({:.0} configs/s, op-cache hit-rate {:.0}% [mem {:.0}% / disk {:.0}%], {} distinct ops{prune_note}{goodput_note})",
            rs.summary.usize_at("evaluated").unwrap_or(rs.rows.len()),
            std::time::Duration::from_secs_f64(
                rs.summary.f64_at("elapsed_us").unwrap_or(0.0) / 1e6
            ),
            rs.summary.f64_at("configs_per_sec").unwrap_or(0.0),
            rs.summary.f64_at("cache_hit_rate").unwrap_or(0.0) * 100.0,
            rs.summary.f64_at("cache_memory_hit_rate").unwrap_or(0.0) * 100.0,
            rs.summary.f64_at("cache_disk_hit_rate").unwrap_or(0.0) * 100.0,
            rs.summary.usize_at("distinct_ops").unwrap_or(0)
        );
        return Ok(0);
    }

    // retry knobs only shape the remote reconnect loop
    for opt in ["retries", "backoff-ms"] {
        anyhow::ensure!(
            !args.is_explicit(opt),
            "--{opt} only applies with --remote (a local sweep has no connection to retry)"
        );
    }
    let (reg, reg_hash) = registry_for(&platform, &args.str("forests"), args.u64("seed")?)?;
    let use_xla = args.has_flag("xla");
    let mut backend = backend_for(reg, use_xla)?;
    let jobs = args.usize("jobs")?;
    let mut engine = crate::sweep::Engine::new();
    if jobs > 0 {
        engine = engine.with_threads(jobs);
    }
    let trace_out = args.str("trace-out");
    if !trace_out.is_empty() {
        crate::obs::enable();
    }
    let cache_dir = args.str("cache-dir");
    let persist = if cache_dir.is_empty() {
        None
    } else {
        let fp = cache_fingerprint(reg_hash, &platform, use_xla);
        let path = op_cache_path(&cache_dir, &platform, fp);
        let loaded = {
            let _g = crate::obs::span("op-cache load", "cache");
            engine.cache().load(&path, fp)
        };
        eprintln!("[fgpm] op cache {path:?}: {}", loaded.describe());
        Some((path, fp))
    };
    let report = engine
        .sweep(&model, &platform, &sweep_spec, backend.as_mut())
        .map_err(|e| anyhow!("{e}"))?;
    if let Some((path, fp)) = persist {
        let _g = crate::obs::span("op-cache save", "cache");
        let max_bytes = args.u64("cache-max-mb")?.checked_mul(1024 * 1024).filter(|&b| b > 0);
        if let Err(e) = engine.cache().save_capped(&path, fp, max_bytes) {
            eprintln!("[fgpm] WARNING: could not save op cache {path:?}: {e}");
        }
    }
    if !trace_out.is_empty() {
        crate::obs::disable();
        let spans = crate::obs::drain();
        std::fs::write(&trace_out, crate::obs::spans_to_trace_json(&spans).to_string())
            .with_context(|| format!("writing --trace-out {trace_out}"))?;
        eprintln!("[fgpm] wrote {} engine spans -> {trace_out}", spans.len());
    }
    if sweep_spec.faults.is_some() {
        let rows: Vec<(String, f64, f64, f64, f64, f64)> = report
            .rows
            .iter()
            .map(|r| {
                let (g, u, c) = r
                    .goodput
                    .map(|g| (g.goodput_frac, g.useful_flop_frac, g.ckpt_overhead_frac))
                    .unwrap_or((0.0, 0.0, 0.0));
                (r.par.label(), r.seconds(), r.mem_gib, g, u, c)
            })
            .collect();
        print!(
            "{}",
            crate::report::tables::goodput_sweep_table_text(
                &title,
                &rows,
                report.skipped_oom,
                report.skipped_sched,
                report.skipped_microbatch,
                platform.gpu.hbm_gib
            )
        );
    } else {
        let rows: Vec<(String, f64, f64)> = report
            .rows
            .iter()
            .map(|r| (r.par.label(), r.seconds(), r.mem_gib))
            .collect();
        print!(
            "{}",
            crate::report::tables::sweep_table_text(
                &title,
                &rows,
                report.skipped_oom,
                report.skipped_sched,
                report.skipped_microbatch,
                platform.gpu.hbm_gib
            )
        );
    }
    let prune_note = if report.pruned > 0 {
        format!(
            ", pruned {} of {} configs via bound ({:.0}%)",
            report.pruned,
            report.evaluated + report.pruned,
            report.pruned_frac() * 100.0
        )
    } else {
        String::new()
    };
    let goodput_note = if sweep_spec.faults.is_some() {
        format!(
            ", best goodput {:.1}% (useful FLOPs {:.1}%)",
            report.best_goodput_frac() * 100.0,
            report.best_useful_flop_frac() * 100.0
        )
    } else {
        String::new()
    };
    println!(
        "evaluated {} configs in {:.0?} ({:.0} configs/s, {}{prune_note}{goodput_note})",
        report.evaluated,
        report.elapsed,
        report.configs_per_sec(),
        cache_stats_line(&report.cache)
    );
    Ok(0)
}

/// `fgpm serve-plan`: rank (tp x replicas, max-batch) inference
/// deployments against a QPS target and a p99 per-token latency SLO.
/// Prefill/decode phases lower to the same operator families as
/// training and flow through the engine's shared op cache; the disk
/// cache (if any) carries the serving-family fingerprint dimension so
/// decode-shaped predictions never collide into a training cache file.
fn cmd_serve_plan(argv: &[String]) -> Result<i32> {
    let spec = Spec::new(
        "serve-plan",
        "rank (tp x replicas, max-batch) serving deployments against a QPS \
         target and a p99 per-output-token latency SLO (deterministic \
         continuous-batching simulation of the offered load)",
    )
    .opt("model", "llemma7b", "model preset")
    .opt("platform", "perlmutter", "target platform")
    .opt("gpus", "8", "total GPUs (every deployment uses all of them)")
    .opt("qps", "4", "offered load the plan must sustain, requests/second")
    .opt("slo-p99-ms", "200", "p99 per-output-token latency SLO, milliseconds")
    .opt("arrival", "poisson", "arrival process (poisson | fixed)")
    .opt("prompt-tokens", "512", "prompt (prefill) length per request, tokens")
    .opt("output-tokens", "128", "generated (decode) length per request, tokens")
    .opt("max-tp", "8", "tensor-parallel cap (powers of two, at most one node)")
    .opt("max-batch", "1,4,8,16,32", "candidate max concurrent batch sizes (comma list)")
    .opt("topo", "flat", "fabric shape (flat | rail:<nodes_per_rail>[:<spine_bw_frac>])")
    .opt("cache-dir", "", "disk-persist the op-prediction cache in this directory")
    .opt("forests", "forests", "trained registry directory")
    .opt("seed", "7", "rng seed (arrival stream + in-process training fallback)")
    .flag("xla", "serve inference from the AOT Pallas executable (PJRT)");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let platform = apply_topo_arg(&args, platform_arg(&args)?)?;
    let model = model_arg(&args)?;
    let gpus = args.usize("gpus")?;
    anyhow::ensure!(gpus >= 1, "--gpus must be >= 1");
    let qps = args.f64("qps")?;
    anyhow::ensure!(qps.is_finite() && qps > 0.0, "--qps must be positive, got {qps}");
    let slo_p99_ms = args.f64("slo-p99-ms")?;
    anyhow::ensure!(
        slo_p99_ms.is_finite() && slo_p99_ms > 0.0,
        "--slo-p99-ms must be positive, got {slo_p99_ms}"
    );
    let arrival = ArrivalKind::parse(&args.str("arrival")).ok_or_else(|| {
        anyhow!("--arrival expects poisson|fixed, got '{}'", args.str("arrival"))
    })?;
    let prompt_tokens = args.usize("prompt-tokens")?;
    let output_tokens = args.usize("output-tokens")?;
    anyhow::ensure!(
        prompt_tokens >= 1 && output_tokens >= 1,
        "--prompt-tokens and --output-tokens must be >= 1"
    );
    let max_tp = args.usize("max-tp")?;
    anyhow::ensure!(max_tp >= 1, "--max-tp must be >= 1");
    let max_batches =
        list_arg(&args, "max-batch", |s| s.parse::<usize>().ok().filter(|&n| n >= 1))?;
    let load = ServingLoad {
        qps,
        slo_p99_ms,
        arrival,
        prompt_tokens,
        output_tokens,
        seed: args.u64("seed")?,
    };
    let plan_spec = crate::sweep::ServePlanSpec { gpus, max_tp, max_batches, load };
    let workload = WorkloadKind::Serving(load);

    let (reg, reg_hash) = registry_for(&platform, &args.str("forests"), args.u64("seed")?)?;
    let use_xla = args.has_flag("xla");
    let mut backend = backend_for(reg, use_xla)?;
    let engine = crate::sweep::Engine::new();
    let cache_dir = args.str("cache-dir");
    let persist = if cache_dir.is_empty() {
        None
    } else {
        let fp = cache_fingerprint_for(reg_hash, &platform, use_xla, &workload);
        let path = op_cache_path(&cache_dir, &platform, fp);
        eprintln!("[fgpm] op cache {path:?}: {}", engine.cache().load(&path, fp).describe());
        Some((path, fp))
    };
    let report = engine
        .serve_plan(&model, &platform, &plan_spec, backend.as_mut())
        .map_err(|e| anyhow!("{e}"))?;
    if let Some((path, fp)) = persist {
        if let Err(e) = engine.cache().save(&path, fp) {
            eprintln!("[fgpm] WARNING: could not save op cache {path:?}: {e}");
        }
    }
    let title = format!(
        "{} serving on {} with {} GPUs — {} qps @ {}+{} tokens, p99 SLO {} ms/token ({} arrivals):",
        model.name,
        platform.name,
        gpus,
        qps,
        prompt_tokens,
        output_tokens,
        slo_p99_ms,
        arrival.label()
    );
    print!(
        "{}",
        crate::report::tables::serve_plan_table_text(&title, &report, platform.gpu.hbm_gib)
    );
    println!(
        "evaluated {} configs in {:.0?} ({:.0} configs/s, {})",
        report.evaluated,
        report.elapsed,
        report.configs_per_sec(),
        cache_stats_line(&report.cache)
    );
    Ok(0)
}

fn cmd_goodput(argv: &[String]) -> Result<i32> {
    let spec = Spec::new(
        "goodput",
        "checkpoint-interval x MTBF goodput grid for one configuration \
         (closed-form Daly/Young estimate, cross-checked against the \
         fault event simulator at the starred cell)",
    )
    .opt("model", "gpt20b", "model preset")
    .opt("parallel", "4-4-8", "pp-mp-dp[/schedule][@rank-map]")
    .opt("platform", "perlmutter", "target platform")
    .opt("topo", "flat", "fabric shape (flat | rail:<nodes_per_rail>[:<spine_bw_frac>])")
    .opt("mtbf-gpu-h", "10000,40000,160000", "per-GPU MTBF values to cross, hours (comma list)")
    .opt("ckpt-interval", "16,64,256,1024", "checkpoint intervals to cross, steps (comma list)")
    .opt("straggler-prob", "0.02", "per-step straggler probability [0,1]")
    .opt("straggler-mult", "1.15", "step multiplier when a straggler strikes (>= 1)")
    .opt("sim-steps", "2000", "event-simulated steps for the cross-check line")
    .opt("forests", "forests", "trained registry directory")
    .opt("seed", "7", "rng seed")
    .flag("xla", "use the AOT Pallas executable");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let platform = apply_topo_arg(&args, platform_arg(&args)?)?;
    let model = model_arg(&args)?;
    let par = ParallelCfg::parse(&args.str("parallel"))
        .context("bad --parallel (expected pp-mp-dp[/schedule][@rank-map])")?;
    validate_schedule(&model, &par)?;
    anyhow::ensure!(par.fits(&platform), "{} needs {} GPUs", par.label(), par.gpus());
    let intervals = list_arg(&args, "ckpt-interval", |s| {
        s.parse::<usize>().ok().filter(|&n| n >= 1)
    })?;
    let mtbfs = list_arg(&args, "mtbf-gpu-h", |s| {
        s.parse::<f64>().ok().filter(|m| m.is_finite() && *m > 0.0)
    })?;
    let straggler_prob = args.f64("straggler-prob")?;
    anyhow::ensure!(
        (0.0..=1.0).contains(&straggler_prob),
        "--straggler-prob must be in [0, 1], got {straggler_prob}"
    );
    let straggler_mult = args.f64("straggler-mult")?;
    anyhow::ensure!(straggler_mult >= 1.0, "--straggler-mult must be >= 1, got {straggler_mult}");

    let (reg, _) = registry_for(&platform, &args.str("forests"), args.u64("seed")?)?;
    let mut backend = backend_for(reg, args.has_flag("xla"))?;
    let cp = predict(&model, &par, &platform, backend.as_mut());
    let step_s = cp.total_seconds();

    let params_for = |mtbf_h: f64, interval: usize| {
        let mut fs = crate::faults::FaultSpec::production();
        fs.mtbf_gpu_h = mtbf_h;
        fs.straggler_prob = straggler_prob;
        fs.straggler_mult = straggler_mult;
        let plan = crate::faults::FaultPlan::new(fs, interval);
        crate::faults::GoodputParams::resolve(&model, &par, &platform, &plan, step_s)
    };
    let mut grid: Vec<Vec<f64>> = Vec::with_capacity(intervals.len());
    let mut optimal_s: Vec<f64> = Vec::new();
    for (i, &interval) in intervals.iter().enumerate() {
        let mut row = Vec::with_capacity(mtbfs.len());
        for &mtbf_h in &mtbfs {
            let est = crate::faults::closed_form(&params_for(mtbf_h, interval));
            row.push(est.goodput_frac);
            if i == 0 {
                // λ and δ do not depend on the interval: one Young
                // optimum per MTBF column
                optimal_s.push(est.optimal_ckpt_interval_s);
            }
        }
        grid.push(row);
    }
    let p0 = params_for(mtbfs[0], intervals[0]);
    let title = format!(
        "{} {} on {} — closed-form goodput (step {:.2} s, ckpt write {:.1} s, restart {:.1} s):",
        model.name,
        par.label(),
        platform.name,
        step_s,
        p0.ckpt_write_s,
        p0.restart_s
    );
    print!(
        "{}",
        crate::report::tables::goodput_grid_text(&title, &intervals, &mtbfs, &grid, &optimal_s)
    );

    // cross-check the starred cell against the event simulation
    let (mut bi, mut bj) = (0, 0);
    for (i, row) in grid.iter().enumerate() {
        for (j, &g) in row.iter().enumerate() {
            if g.total_cmp(&grid[bi][bj]) == std::cmp::Ordering::Greater {
                (bi, bj) = (i, j);
            }
        }
    }
    let p = params_for(mtbfs[bj], intervals[bi]);
    let sim_steps = args.usize("sim-steps")?.max(1);
    let sim = crate::faults::simulate(&p, sim_steps, args.u64("seed")?);
    let sim_frac = sim.goodput_frac(step_s);
    println!(
        "event-sim cross-check at ckpt {} x mtbf {:.0}h over {} steps: closed form {:.2}% \
         vs simulated {:.2}% ({} failures, {} stragglers, {} checkpoints)",
        intervals[bi],
        mtbfs[bj],
        sim_steps,
        grid[bi][bj] * 100.0,
        sim_frac * 100.0,
        sim.failures,
        sim.stragglers,
        sim.checkpoints
    );
    Ok(0)
}

fn cmd_topo(argv: &[String]) -> Result<i32> {
    let spec = Spec::new(
        "topo",
        "print the cluster tier graph, group geometries under the rank map, and the \
         group->tier traffic matrix — crossing counts AND per-tier bytes \
         (incl. the interleaved wrap-around hop's path)",
    )
    .opt("model", "gpt20b", "model preset (sets the per-transfer traffic volumes)")
    .opt("parallel", "4-4-8", "pp-mp-dp[@rank-map]")
    .opt("platform", "perlmutter", "target platform")
    .opt("rank-map", "tp-first", "rank placement (tp-first|dp-first|pp-first)")
    .opt("topo", "flat", "fabric shape (flat | rail:<nodes_per_rail>[:<spine_bw_frac>])")
    .opt("payload-mb", "25", "reference P2P payload for the per-boundary times, MB");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let platform = apply_topo_arg(&args, platform_arg(&args)?)?;
    let model = model_arg(&args)?;
    let par = ParallelCfg::parse(&args.str("parallel"))
        .context("bad --parallel (expected pp-mp-dp[@rank-map])")?;
    let par = apply_rank_map_arg(&args, par)?;
    anyhow::ensure!(par.fits(&platform), "{} needs {} GPUs", par.label(), par.gpus());
    let payload_mb = args.f64("payload-mb")?;
    anyhow::ensure!(payload_mb > 0.0, "--payload-mb must be positive");
    let md = crate::report::tables::topo_markdown(&model, &par, &platform, payload_mb);
    println!("{}", report::emit("topo.md", &md));
    Ok(0)
}

fn cmd_schedules(argv: &[String]) -> Result<i32> {
    let spec = Spec::new(
        "schedules",
        "compare 1F1B / GPipe / interleaved-1F1B / ZB-H1 for one configuration \
         (event-accurate sim vs per-schedule closed form, with a comm-exposure column)",
    )
    .opt("model", "gpt20b", "model preset")
    .opt("parallel", "4-4-8", "pp-mp-dp")
    .opt("platform", "perlmutter", "target platform")
    .opt("chunks", "2", "virtual chunks per stage for interleaved-1F1B")
    .opt("p2p-overlap", "0", "fraction of PP P2P overlapped with compute [0,1]")
    .opt("batches", "4", "measured batches per schedule (fastest wins)")
    .opt("seed", "42", "rng seed");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let model = model_arg(&args)?;
    let platform = platform_arg(&args)?;
    let par = ParallelCfg::parse(&args.str("parallel"))
        .context("bad --parallel (expected pp-mp-dp)")?;
    anyhow::ensure!(
        par.schedule == ScheduleKind::OneFOneB,
        "this command compares ALL schedules; drop the /{} suffix from --parallel",
        par.schedule.label()
    );
    let par = apply_overlap_arg(&args, par)?;
    let chunks = args.usize("chunks")?;
    anyhow::ensure!(chunks >= 2, "--chunks must be >= 2 (interleaving needs multiple virtual chunks)");
    let md = crate::report::tables::schedule_compare_markdown(
        &model,
        &par,
        &platform,
        chunks,
        args.usize("batches")?,
        args.u64("seed")?,
    )
    .map_err(|e| anyhow!("{e}"))?;
    println!("{}", report::emit("schedules.md", &md));
    Ok(0)
}

fn cmd_table8(argv: &[String]) -> Result<i32> {
    let spec = Spec::new("table8", "Table VIII: batch-time stability statistics")
        .opt("batches", "20", "measured batches per configuration")
        .opt("seed", "42", "rng seed");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let md = table8_markdown(args.usize("batches")?, args.u64("seed")?);
    println!("{}", report::emit("table8.md", &md));
    Ok(0)
}

fn cmd_table9(argv: &[String]) -> Result<i32> {
    let spec = Spec::new("table9", "Table IX: component-level prediction errors")
        .opt("batches", "8", "ground-truth batches per config (fastest wins)")
        .opt("forests", "forests", "trained registry directory")
        .opt("seed", "42", "rng seed")
        .flag("xla", "serve inference from the AOT Pallas executable");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let seed = args.u64("seed")?;
    let n = args.usize("batches")?;
    let mut results = Vec::new();
    for platform in Platform::all() {
        let (reg, _) = registry_for(&platform, &args.str("forests"), seed)?;
        let mut backend = backend_for(reg, args.has_flag("xla"))?;
        let errs =
            crate::report::tables::table9_errors(&platform, backend.as_mut(), n, seed);
        results.push((platform.name.to_string(), errs));
    }
    let md = table9_markdown(&results);
    println!("{}", report::emit("table9.md", &md));
    Ok(0)
}

fn cmd_fig2(argv: &[String]) -> Result<i32> {
    let spec = Spec::new("fig2", "Figure 2: pipeline schedule timelines (ASCII)")
        .opt("model", "gpt20b", "model preset")
        .opt("parallel", "4-4-8", "pp-mp-dp[/schedule]")
        .opt("platform", "perlmutter", "target platform")
        .opt("schedule", "1f1b", "schedule for the measured-shape timeline (incl. zb-h1)")
        .opt("p2p-overlap", "0", "fraction of PP P2P overlapped with compute [0,1]");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let par = ParallelCfg::parse(&args.str("parallel")).context("bad --parallel")?;
    let par = apply_overlap_arg(&args, apply_schedule_arg(&args, par)?)?;
    let md = fig2_markdown(&model_arg(&args)?, &par, &platform_arg(&args)?);
    println!("{}", report::emit("fig2.md", &md));
    Ok(0)
}

fn cmd_fig3(argv: &[String]) -> Result<i32> {
    let spec = Spec::new("fig3", "Figure 3: component time-cost proportions")
        .opt("forests", "forests", "trained registry directory")
        .opt("seed", "42", "rng seed")
        .flag("xla", "use the AOT Pallas executable");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let mut out = String::new();
    for platform in Platform::all() {
        let (reg, _) = registry_for(&platform, &args.str("forests"), args.u64("seed")?)?;
        let mut backend = backend_for(reg, args.has_flag("xla"))?;
        out.push_str(&fig3_markdown(&platform, backend.as_mut()));
        out.push('\n');
    }
    println!("{}", report::emit("fig3.md", &out));
    Ok(0)
}

fn cmd_ablate(argv: &[String]) -> Result<i32> {
    let spec = Spec::new("ablate", "regressors vs analytical / log-linear baselines")
        .opt("platform", "perlmutter", "target platform")
        .opt("batches", "6", "ground-truth batches per config")
        .opt("seed", "42", "rng seed");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let platform = platform_arg(&args)?;
    let seed = args.u64("seed")?;
    let n = args.usize("batches")?;
    let data = collect_platform(&platform, seed);
    let reg = Registry::train(platform.name, &data, seed);
    let mut rows = Vec::new();
    let mut run = |name: &str, p: &mut dyn BatchPredictor| {
        let errs = crate::report::tables::table9_errors(&platform, p, n, seed);
        let mean_abs =
            stats::mean(&errs.iter().map(|e| e.overall.abs()).collect::<Vec<_>>());
        let worst = errs.iter().map(|e| e.overall.abs()).fold(0.0, f64::max);
        rows.push(vec![
            name.to_string(),
            format!("{mean_abs:.2}%"),
            format!("{worst:.2}%"),
        ]);
    };
    run("tree regressors (ours)", &mut { reg });
    run("log-linear regression", &mut LogLinear::train(&data));
    run("analytical roofline", &mut Analytical::new(platform.clone()));
    let md = format!(
        "# Ablation — end-to-end |error| by operator-latency model ({})\n\n{}",
        platform.name,
        crate::report::tables::markdown_table(
            &["model".into(), "mean |overall err|".into(), "worst |overall err|".into()],
            &rows
        )
    );
    println!("{}", report::emit(&format!("ablate_{}.md", platform.name), &md));
    Ok(0)
}

fn cmd_serve(argv: &[String]) -> Result<i32> {
    let spec = Spec::new("serve", "JSON-lines TCP prediction service (predict/stats/ping/sweep)")
        .opt("addr", "127.0.0.1:7070", "bind address")
        .opt("platform", "perlmutter", "platform whose regressors to serve")
        .opt("forests", "forests", "trained registry directory")
        .opt("cache-dir", "", "disk-persist the op-prediction cache in this directory")
        .opt("jobs", "0", "sweep evaluation worker threads (0 = one per core)")
        .opt("max-conns", "64", "concurrent-connection cap (excess sheds {\"error\":\"busy\"})")
        .opt("read-timeout-ms", "60000", "per-connection socket read/write timeout")
        .opt("workers", "8", "connection worker pool size")
        .opt("drain-timeout-ms", "5000", "graceful-shutdown budget for in-flight connections")
        .opt("request-timeout-ms", "0", "per-sweep deadline, aborts with a typed error (0 = off)")
        .opt("cache-max-mb", "0", "cap the persisted op-cache file, LRU-evicting (0 = unlimited)")
        .opt("seed", "7", "rng seed")
        .opt("max-batch", "256", "dynamic batcher max rows")
        .opt("max-wait-ms", "2", "dynamic batcher deadline")
        .flag("xla", "serve inference from the AOT Pallas executable");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let platform = platform_arg(&args)?;
    let (reg, reg_hash) = registry_for(&platform, &args.str("forests"), args.u64("seed")?)?;
    let use_xla = args.has_flag("xla");
    let mut svc = PredictionService::start_with(
        move || backend_for(reg, use_xla).expect("backend init"),
        BatcherCfg {
            max_batch: args.usize("max-batch")?,
            max_wait: std::time::Duration::from_millis(args.u64("max-wait-ms")?),
        },
    )
    .with_sweep_threads(args.usize("jobs")?);
    let cache_dir = args.str("cache-dir");
    if !cache_dir.is_empty() {
        let fp = cache_fingerprint(reg_hash, &platform, use_xla);
        svc = svc.with_cache_persist(op_cache_path(&cache_dir, &platform, fp), fp);
    }
    svc = svc.with_cache_max_bytes(args.u64("cache-max-mb")? * 1024 * 1024);
    let request_timeout_ms = args.u64("request-timeout-ms")?;
    let opts = server::ServeOpts {
        max_conns: args.usize("max-conns")?.max(1),
        read_timeout: std::time::Duration::from_millis(args.u64("read-timeout-ms")?.max(1)),
        workers: args.usize("workers")?.max(1),
        drain_timeout: std::time::Duration::from_millis(args.u64("drain-timeout-ms")?),
        request_timeout: (request_timeout_ms > 0)
            .then(|| std::time::Duration::from_millis(request_timeout_ms)),
    };
    server::install_sigterm_handler();
    server::serve_opts(svc, &args.str("addr"), opts)?;
    Ok(0)
}

fn cmd_e2e(argv: &[String]) -> Result<i32> {
    let spec = Spec::new("e2e", "full pipeline on both platforms (collect->train->validate)")
        .opt("batches", "8", "ground-truth batches per config")
        .opt("seed", "42", "rng seed")
        .flag("xla", "use the AOT Pallas executable for inference");
    let Some(args) = parse_or_help(&spec, argv)? else { return Ok(0) };
    let seed = args.u64("seed")?;
    let n = args.usize("batches")?;
    let mut results = Vec::new();
    for platform in Platform::all() {
        println!("== {} ==", platform.name);
        let t0 = std::time::Instant::now();
        let data = collect_platform(&platform, seed);
        println!(
            "  collected {} datasets ({} rows) in {:?}",
            data.len(),
            data.values().map(|d| d.len()).sum::<usize>(),
            t0.elapsed()
        );
        let t0 = std::time::Instant::now();
        let reg = Registry::train(platform.name, &data, seed);
        println!(
            "  trained {} regressors in {:?} (mean val MAPE {:.2}%)",
            reg.forests.len(),
            t0.elapsed(),
            reg.mean_val_mape()
        );
        let mut backend = backend_for(reg, args.has_flag("xla"))?;
        let t0 = std::time::Instant::now();
        let errs = crate::report::tables::table9_errors(&platform, backend.as_mut(), n, seed);
        println!("  validated 5 configs in {:?}", t0.elapsed());
        for e in &errs {
            println!(
                "    {:<18} actual {:>7.2}s predicted {:>7.2}s overall {:+.2}%",
                e.label, e.actual_total_s, e.predicted_total_s, e.overall
            );
        }
        results.push((platform.name.to_string(), errs));
    }
    let md = table9_markdown(&results);
    report::emit("e2e.md", &md);
    for (plat, errs) in &results {
        let mean = stats::mean(&errs.iter().map(|e| e.overall.abs()).collect::<Vec<_>>());
        println!("mean |overall error| {plat}: {mean:.2}%");
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_config_flag_cluster_agrees_across_variants() {
        let base = with_config_flags(Spec::new("x", "y"), false);
        let sweep = with_config_flags(Spec::new("x", "y"), true);
        for spec in [&base, &sweep] {
            for name in CONFIG_FLAG_NAMES {
                let o = spec
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .unwrap_or_else(|| panic!("missing --{name}"));
                assert!(!o.is_flag, "--{name} must take a value");
            }
        }
        // identical names AND defaults in both variants (only the help
        // wording differs — sweep's --schedule/--rank-map also take `all`)
        assert_eq!(base.opts.len(), CONFIG_FLAG_NAMES.len());
        assert_eq!(base.opts.len(), sweep.opts.len());
        for (a, b) in base.opts.iter().zip(&sweep.opts) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.default, b.default);
        }
    }

    #[test]
    fn shared_cluster_parses_to_the_documented_defaults() {
        let spec = with_config_flags(Spec::new("x", "y"), false);
        let args = spec.parse(&[]).unwrap();
        assert_eq!(args.str("schedule"), "1f1b");
        assert_eq!(args.f64("p2p-overlap").unwrap(), 0.0);
        assert_eq!(args.str("rank-map"), "tp-first");
        assert_eq!(args.str("topo"), "flat");
        assert_eq!(args.str("cache-dir"), "");
        for name in CONFIG_FLAG_NAMES {
            assert!(!args.is_explicit(name));
        }
    }

    #[test]
    fn training_cache_fingerprint_is_byte_stable() {
        let p = Platform::perlmutter();
        // the pre-workload 3-part hash, spelled out: existing disk cache
        // files must keep their names across the upgrade
        let legacy = opcache::combine_hashes(&[
            42,
            opcache::fnv1a64(format!("{p:?}").as_bytes()),
            opcache::fnv1a64("native".as_bytes()),
        ]);
        assert_eq!(cache_fingerprint(42, &p, false), legacy);
        assert_eq!(cache_fingerprint_for(42, &p, false, &WorkloadKind::training()), legacy);
        // a global-batch override is still the training FAMILY: same file
        let big = WorkloadKind::Training { global_batch: Some(4096) };
        assert_eq!(cache_fingerprint_for(42, &p, false, &big), legacy);
        // serving lands in its own file
        let serving = WorkloadKind::Serving(ServingLoad::default());
        assert_ne!(cache_fingerprint_for(42, &p, false, &serving), legacy);
    }
}
