//! Ground-truth cluster simulator: executes lowered operators on a
//! platform, returning latency samples = deterministic structure x
//! stochastic jitter.
//!
//! This module is the stand-in for the paper's physical testbeds
//! (DESIGN.md §2). Everything downstream — the micro-benchmark collector,
//! the "real" training runs of Table VIII, and the prediction targets of
//! Table IX — measures *this* simulator, never the analytic formulas
//! directly, so the regressors face the same estimation problem the paper
//! did: noisy samples of a discontinuous surface.

use crate::config::Platform;
use crate::hw::{gemm_time_us, membound_time_us};
use crate::net::topology::{p2p_path_time_us, TierLevel};
use crate::net::{allgather_fabric_time_us, allreduce_fabric_time_us};
use crate::ops::LoweredOp;
use crate::util::rng::Rng;

/// Spine hops sit behind an extra switching stage with adaptive routing:
/// their jitter sigma is amplified relative to the rail tier.
const SPINE_SIGMA_FACTOR: f64 = 1.5;

/// A simulated cluster: a platform plus a jitter stream.
pub struct ClusterSim {
    pub platform: Platform,
    rng: Rng,
    /// Correlated inter-node slowdown for the current epoch (>= 1).
    fabric_mult: f64,
}

impl ClusterSim {
    pub fn new(platform: Platform, seed: u64) -> ClusterSim {
        let mut sim =
            ClusterSim { platform, rng: Rng::new(seed ^ 0xC1_05_7E_25), fabric_mult: 1.0 };
        sim.new_epoch();
        sim
    }

    /// Draw a fresh correlated fabric state scaled by job footprint:
    /// sigma_eff = fabric_sigma * sqrt(nodes / max_nodes). Micro-benchmarks
    /// (<= 8 processes, isolated) barely see it; a 128-node training job
    /// self-congests the fabric — which is exactly why the paper's Vista
    /// predictions are a *conservative lower bound* on measured time and
    /// why Table VIII's spread grows with scale.
    pub fn new_epoch_scaled(&mut self, nodes: usize) {
        let scale = (nodes as f64 / self.platform.max_nodes as f64).clamp(0.0, 1.0).sqrt();
        let sigma = self.platform.jitter.fabric_sigma * scale;
        self.fabric_mult = (sigma * self.rng.normal()).abs().exp();
    }

    /// Epoch draw at benchmark footprint (tiny): effectively clean fabric.
    pub fn new_epoch(&mut self) {
        self.new_epoch_scaled(1);
    }

    /// Current fabric multiplier (test/diagnostic hook).
    pub fn fabric_mult(&self) -> f64 {
        self.fabric_mult
    }

    /// Deterministic (jitter-free) latency of a lowered op, µs. This is
    /// the "true" mean structure the regressors try to recover.
    pub fn deterministic_us(&self, op: &LoweredOp) -> f64 {
        deterministic_us(op, &self.platform)
    }

    /// One measured latency sample, µs (deterministic x jitter x epoch
    /// fabric state for inter-node communication).
    pub fn sample_us(&mut self, op: &LoweredOp) -> f64 {
        let base = deterministic_us(op, &self.platform);
        let fabric = if op.is_comm() && op.is_inter_node() { self.fabric_mult } else { 1.0 };
        base * self.jitter_factor(op) * fabric
    }

    /// Multiplicative jitter for one execution, by the deepest network
    /// tier the op touches (compute < intra < rail < spine), with one
    /// independent congestion opportunity PER fabric hop rather than a
    /// single global draw — a rail+spine path can get unlucky twice.
    fn jitter_factor(&mut self, op: &LoweredOp) -> f64 {
        let j = &self.platform.jitter;
        let sigma = match op.worst_tier() {
            None => j.compute_sigma,
            Some(TierLevel::Intra) => j.intra_comm_sigma,
            Some(TierLevel::Rail) => j.inter_comm_sigma,
            Some(TierLevel::Spine) => j.inter_comm_sigma * SPINE_SIGMA_FACTOR,
        };
        let mut f = self.rng.lognormal(sigma);
        for _ in 0..op.fabric_hops() {
            if self.rng.chance(j.congestion_prob) {
                f *= j.congestion_mult;
            }
        }
        f
    }
}

/// Deterministic latency of a lowered op on a platform, µs.
pub fn deterministic_us(op: &LoweredOp, platform: &Platform) -> f64 {
    match op {
        LoweredOp::Gemm(shape) => gemm_time_us(shape, &platform.gpu),
        LoweredOp::Mem { kind, elems, elem_bytes, rows } => {
            membound_time_us(*kind, *elems, *elem_bytes, *rows, &platform.gpu)
        }
        LoweredOp::Flash { flops, bytes } => {
            // Flash attention sustains ~55-65% of peak on long sequences;
            // short sequences are bandwidth/launch limited.
            let gpu = &platform.gpu;
            let t_compute = flops / (gpu.peak_tflops_fp16 * 1e12 * 0.60) * 1e6;
            let t_mem = bytes / (gpu.mem_bw_gbs * 1e9) * 1e6;
            t_compute.max(t_mem) + gpu.launch_us
        }
        LoweredOp::AllReduce { bytes, geom, fabric } => {
            allreduce_fabric_time_us(*bytes, *geom, fabric, platform)
        }
        LoweredOp::AllGather { bytes_out, geom, fabric } => {
            allgather_fabric_time_us(*bytes_out, *geom, fabric, platform)
        }
        LoweredOp::P2p { bytes, path } => p2p_path_time_us(*bytes, path, platform.gpu.launch_us),
        LoweredOp::Seq(v) => v.iter().map(|o| deterministic_us(o, platform)).sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelCfg, ParallelCfg};
    use crate::ops::build::{compute_op, mp_allreduce, Workload};
    use crate::ops::{Dir, OpKind};
    use crate::util::stats;

    fn sim_p() -> ClusterSim {
        ClusterSim::new(Platform::perlmutter(), 1)
    }

    fn wl() -> Workload {
        Workload::new(
            &ModelCfg::gpt20b(),
            &ParallelCfg::new(4, 4, 8),
            &Platform::perlmutter(),
        )
    }

    #[test]
    fn samples_center_on_deterministic() {
        let mut sim = sim_p();
        let op = compute_op(OpKind::Linear1, &wl(), Dir::Fwd).lowered;
        let det = sim.deterministic_us(&op);
        let samples: Vec<f64> = (0..200).map(|_| sim.sample_us(&op)).collect();
        let med = stats::median(&samples);
        assert!((med - det).abs() / det < 0.02, "det {det} med {med}");
    }

    #[test]
    fn compute_jitter_small_comm_jitter_large_on_vista() {
        let mut sim = ClusterSim::new(Platform::vista(), 2);
        let w = Workload::new(
            &ModelCfg::gpt20b(),
            &ParallelCfg::new(4, 8, 4),
            &Platform::vista(),
        );
        let gemm = compute_op(OpKind::Linear1, &w, Dir::Fwd).lowered;
        let ar = mp_allreduce(&w).lowered;
        let cv = |xs: &[f64]| stats::stddev(xs) / stats::mean(xs);
        let g: Vec<f64> = (0..300).map(|_| sim.sample_us(&gemm)).collect();
        let a: Vec<f64> = (0..300).map(|_| sim.sample_us(&ar)).collect();
        assert!(cv(&a) > 5.0 * cv(&g), "comm cv {} gemm cv {}", cv(&a), cv(&g));
    }

    #[test]
    fn seq_is_sum() {
        let sim = sim_p();
        let a = compute_op(OpKind::Linear1, &wl(), Dir::Fwd).lowered;
        let b = compute_op(OpKind::Glue, &wl(), Dir::Fwd).lowered;
        let seq = crate::ops::LoweredOp::Seq(vec![a.clone(), b.clone()]);
        let s = sim.deterministic_us(&seq);
        assert!((s - sim.deterministic_us(&a) - sim.deterministic_us(&b)).abs() < 1e-9);
    }

    #[test]
    fn bwd_slower_than_fwd() {
        let sim = sim_p();
        for kind in [OpKind::Linear1, OpKind::QkT, OpKind::LayerNorm] {
            let f = sim.deterministic_us(&compute_op(kind, &wl(), Dir::Fwd).lowered);
            let b = sim.deterministic_us(&compute_op(kind, &wl(), Dir::Bwd).lowered);
            assert!(b > 1.2 * f, "{kind:?}: fwd {f} bwd {b}");
        }
    }

    #[test]
    fn encoder_fwd_magnitude_sane() {
        // GPT-20B mp=4 on A100: one encoder fwd micro-batch should land in
        // the ~5-60ms band (the paper's stage times imply tens of ms).
        let sim = sim_p();
        let m = ModelCfg::gpt20b();
        let total: f64 = crate::ops::build::encoder_ops(&m, &wl(), Dir::Fwd)
            .iter()
            .map(|o| sim.deterministic_us(&o.lowered))
            .sum();
        assert!((3_000.0..60_000.0).contains(&total), "{total} µs");
    }

    #[test]
    fn gh200_runs_compute_faster() {
        let sp = ClusterSim::new(Platform::perlmutter(), 3);
        let sv = ClusterSim::new(Platform::vista(), 3);
        let op = compute_op(OpKind::Linear3, &wl(), Dir::Fwd).lowered;
        assert!(sv.deterministic_us(&op) < sp.deterministic_us(&op));
    }

    #[test]
    fn deterministic_reproducible() {
        let s1 = ClusterSim::new(Platform::perlmutter(), 9);
        let s2 = ClusterSim::new(Platform::perlmutter(), 10);
        let op = compute_op(OpKind::AttnV, &wl(), Dir::Fwd).lowered;
        assert_eq!(s1.deterministic_us(&op), s2.deterministic_us(&op));
    }
}
