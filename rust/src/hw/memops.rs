//! Memory-bandwidth-bound operator latency model (normalization,
//! activations, softmax variants, RoPE, embedding gathers, optimizer
//! updates, elementwise glue).
//!
//! Behaviour reproduced from real GPUs:
//! - **cache-regime cliff**: working sets that fit L2 stream at L2
//!   bandwidth; larger ones fall to HBM bandwidth, with a smooth-but-fast
//!   transition (regressors see a bend, not an analytic line);
//! - **pass count**: unfused ops read/write the tensor multiple times
//!   (e.g. naive softmax = 5 passes; fused = ~2);
//! - **reduction overhead**: row reductions (norms, softmax) add a
//!   latency term per row wave;
//! - **launch overhead** per kernel.

use crate::config::platform::GpuSpec;

/// Class of memory-bound operator; `passes()` encodes the effective number
/// of full-tensor traversals (reads + writes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemOpKind {
    /// LayerNorm: mean+var reduction, normalize, affine (2 read + 1 write).
    LayerNorm,
    /// RMSNorm: single reduction (cheaper than LayerNorm).
    RmsNorm,
    /// Naive softmax: max, sub-exp, sum, div — 5 effective passes.
    Softmax,
    /// Fused softmax: one read, one write + registers.
    FusedSoftmax,
    /// Additive attention mask fill.
    Fillmask,
    /// Rotary position embedding (read, rotate, write).
    Rope,
    /// GeLU / elementwise activation ("Glue" in Table I).
    Gelu,
    /// Embedding-table gather (row gather + write).
    EmbeddingGather,
    /// Cross-entropy over sharded logits (read logits, reduce).
    CrossEntropy,
    /// FusedAdam parameter update (params+grads+2 moments r/w).
    AdamUpdate,
    /// Generic elementwise copy/add.
    Elementwise,
}

impl MemOpKind {
    /// Effective full-tensor traversals (empirical multipliers).
    pub fn passes(&self) -> f64 {
        match self {
            MemOpKind::LayerNorm => 3.0,
            MemOpKind::RmsNorm => 2.5,
            MemOpKind::Softmax => 5.0,
            MemOpKind::FusedSoftmax => 2.0,
            MemOpKind::Fillmask => 2.0,
            MemOpKind::Rope => 2.2,
            MemOpKind::Gelu => 2.0,
            MemOpKind::EmbeddingGather => 2.0,
            MemOpKind::CrossEntropy => 2.5,
            MemOpKind::AdamUpdate => 7.0, // p, g, m, v read + p, m, v write
            MemOpKind::Elementwise => 2.0,
        }
    }

    /// Does the op perform a row reduction (extra latency per row)?
    pub fn has_reduction(&self) -> bool {
        matches!(
            self,
            MemOpKind::LayerNorm
                | MemOpKind::RmsNorm
                | MemOpKind::Softmax
                | MemOpKind::FusedSoftmax
                | MemOpKind::CrossEntropy
        )
    }
}

/// Effective streaming bandwidth for a working set of `bytes`:
/// L2-resident sets get `l2_bw`, huge sets get HBM, with a logistic
/// transition around the L2 capacity (sharp enough to look like a cliff
/// to a coarse analytical model, learnable by a tree).
pub fn effective_bw_gbs(bytes: f64, gpu: &GpuSpec) -> f64 {
    let l2_bytes = gpu.l2_mib * 1024.0 * 1024.0;
    // position of working set relative to L2, log-scaled
    let x = (bytes / l2_bytes).ln();
    let sig = 1.0 / (1.0 + (-1.6 * x).exp()); // 0 when << L2, 1 when >> L2
    gpu.l2_bw_gbs * (1.0 - sig) + gpu.mem_bw_gbs * sig
}

/// Deterministic latency (µs) for a memory-bound op over `elems` elements
/// of `elem_bytes` (2 for fp16, 4 for fp32), with `rows` reduction rows.
pub fn membound_time_us(kind: MemOpKind, elems: f64, elem_bytes: f64, rows: f64, gpu: &GpuSpec) -> f64 {
    let tensor_bytes = elems * elem_bytes;
    let moved = tensor_bytes * kind.passes();
    let bw = effective_bw_gbs(tensor_bytes, gpu);
    let t_stream = moved / (bw * 1e9) * 1e6;
    let t_reduce = if kind.has_reduction() {
        // one extra warp-synchronous reduction wave per ~SM batch of rows
        let row_waves = (rows / (gpu.sms as f64 * 32.0)).ceil();
        row_waves * 0.8
    } else {
        0.0
    };
    t_stream + t_reduce + gpu.launch_us
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::platform::Platform;

    fn a100() -> crate::config::platform::GpuSpec {
        Platform::perlmutter().gpu
    }

    #[test]
    fn bandwidth_regimes() {
        let g = a100();
        let small = effective_bw_gbs(1024.0 * 1024.0, &g); // 1 MiB << L2
        let large = effective_bw_gbs(4.0 * 1024.0 * 1024.0 * 1024.0, &g); // 4 GiB
        assert!(small > 0.85 * g.l2_bw_gbs, "small-set bw {small}");
        assert!((large - g.mem_bw_gbs).abs() < 0.1 * g.mem_bw_gbs, "large-set bw {large}");
        assert!(small > large);
    }

    #[test]
    fn softmax_slower_than_fused() {
        let g = a100();
        let elems = 4.0 * 16.0 * 2048.0 * 2048.0;
        let naive = membound_time_us(MemOpKind::Softmax, elems, 2.0, 4.0 * 16.0 * 2048.0, &g);
        let fused = membound_time_us(MemOpKind::FusedSoftmax, elems, 2.0, 4.0 * 16.0 * 2048.0, &g);
        assert!(naive > 2.0 * fused, "naive {naive} fused {fused}");
    }

    #[test]
    fn layernorm_vs_rmsnorm() {
        let g = a100();
        let elems = 4.0 * 2048.0 * 6144.0;
        let ln = membound_time_us(MemOpKind::LayerNorm, elems, 2.0, 4.0 * 2048.0, &g);
        let rms = membound_time_us(MemOpKind::RmsNorm, elems, 2.0, 4.0 * 2048.0, &g);
        assert!(ln > rms);
    }

    #[test]
    fn scaling_superlinear_across_l2_cliff() {
        // Crossing the L2 boundary makes per-byte cost jump: doubling a
        // working set that straddles the cliff more than doubles latency.
        let g = a100();
        let l2_elems = g.l2_mib * 1024.0 * 1024.0 / 2.0;
        let t1 = membound_time_us(MemOpKind::Elementwise, l2_elems * 0.5, 2.0, 0.0, &g);
        let t2 = membound_time_us(MemOpKind::Elementwise, l2_elems * 8.0, 2.0, 0.0, &g);
        let per_byte1 = (t1 - g.launch_us) / (l2_elems * 0.5);
        let per_byte2 = (t2 - g.launch_us) / (l2_elems * 8.0);
        assert!(per_byte2 > 1.5 * per_byte1, "{per_byte1} vs {per_byte2}");
    }

    #[test]
    fn adam_dominated_by_state_traffic() {
        let g = a100();
        let params = 300e6; // one pipeline stage of GPT-20B / mp
        let t = membound_time_us(MemOpKind::AdamUpdate, params, 4.0, 0.0, &g);
        // 300M params * 4B * 7 passes / 1.5TB/s ≈ 5.6ms
        assert!((3_000.0..12_000.0).contains(&t), "{t}");
    }

    #[test]
    fn launch_floor_for_tiny_ops() {
        let g = a100();
        let t = membound_time_us(MemOpKind::Elementwise, 128.0, 2.0, 0.0, &g);
        assert!(t >= g.launch_us && t < g.launch_us + 1.0);
    }
}
