//! FP16 tensor-core GEMM latency model with cuBLAS-style behaviours:
//!
//! - **kernel selection**: a small "heuristic table" picks a tile shape by
//!   problem size class, producing step discontinuities exactly where real
//!   cuBLAS switches kernels;
//! - **tile quantization**: partially filled tiles waste MACs;
//! - **wave quantization**: the tail wave of thread blocks underfills SMs;
//! - **K-efficiency**: short accumulation depth cannot hide MMA latency;
//! - **memory bound**: small/narrow GEMMs flip to bandwidth-limited;
//! - **launch overhead**: constant per-kernel cost.
//!
//! Batched GEMMs (attention score/value products) fold the batch dimension
//! into wave occupancy.

use crate::config::platform::GpuSpec;

/// Problem shape for C[m,n] += A[m,k] * B[k,n], repeated `batch` times.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmShape {
    pub batch: usize,
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl GemmShape {
    pub fn new(m: usize, k: usize, n: usize) -> GemmShape {
        GemmShape { batch: 1, m, k, n }
    }

    pub fn batched(batch: usize, m: usize, k: usize, n: usize) -> GemmShape {
        GemmShape { batch, m, k, n }
    }

    pub fn flops(&self) -> f64 {
        2.0 * self.batch as f64 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// FP16 bytes moved (A + B + C), assuming no cache reuse across tiles.
    pub fn bytes(&self) -> f64 {
        2.0 * self.batch as f64
            * (self.m as f64 * self.k as f64
                + self.k as f64 * self.n as f64
                + self.m as f64 * self.n as f64)
    }
}

/// Tile candidates in (tile_m, tile_n, base_efficiency) form. Mirrors the
/// flavor of the cuBLAS kernel zoo: bigger tiles amortize better but only
/// map onto big problems.
const TILES: [(usize, usize, f64); 4] = [
    // base efficiencies calibrated so end-to-end transformer training
    // lands at the ~40-50% MFU real GPT-NeoX runs achieve, not the
    // ~70% of an isolated cuBLAS peak benchmark
    (256, 128, 0.62),
    (128, 128, 0.55),
    (128, 64, 0.47),
    (64, 64, 0.36),
];

/// The auto-tuner: picks the tile maximizing modeled throughput, i.e. the
/// argmin of the compute-time estimate. Returns (tile_m, tile_n, base_eff).
pub fn select_tile(shape: &GemmShape, gpu: &GpuSpec) -> (usize, usize, f64) {
    let mut best = TILES[TILES.len() - 1];
    let mut best_t = f64::INFINITY;
    for &(tm, tn, eff) in &TILES {
        let t = compute_time_with_tile(shape, gpu, tm, tn, eff);
        if t < best_t {
            best_t = t;
            best = (tm, tn, eff);
        }
    }
    best
}

fn compute_time_with_tile(shape: &GemmShape, gpu: &GpuSpec, tm: usize, tn: usize, base_eff: f64) -> f64 {
    let tiles_m = shape.m.div_ceil(tm);
    let tiles_n = shape.n.div_ceil(tn);
    let blocks = shape.batch * tiles_m * tiles_n;

    // tile quantization: fraction of MACs that land inside the matrix
    let util_tile = (shape.m as f64 * shape.n as f64)
        / ((tiles_m * tm) as f64 * (tiles_n * tn) as f64);

    // wave quantization: the tail wave underfills the SM array
    let waves = blocks.div_ceil(gpu.sms);
    let util_wave = blocks as f64 / (waves * gpu.sms) as f64;

    // K-efficiency: short accumulation can't hide MMA pipeline latency
    let k_eff = (shape.k as f64 / (shape.k as f64 + 192.0)).min(1.0);

    let eff = base_eff * util_tile * util_wave * (0.55 + 0.45 * k_eff);
    shape.flops() / (gpu.peak_tflops_fp16 * 1e12 * eff.max(1e-3)) * 1e6 // µs
}

/// Deterministic GEMM latency in µs (jitter-free).
pub fn gemm_time_us(shape: &GemmShape, gpu: &GpuSpec) -> f64 {
    if shape.flops() == 0.0 {
        return gpu.launch_us;
    }
    let (tm, tn, eff) = select_tile(shape, gpu);
    let t_compute = compute_time_with_tile(shape, gpu, tm, tn, eff);
    // memory floor: streaming A/B/C at HBM bandwidth
    let t_mem = shape.bytes() / (gpu.mem_bw_gbs * 1e9) * 1e6;
    t_compute.max(t_mem) + gpu.launch_us
}

/// Achieved TFLOP/s for reporting/roofline checks.
pub fn achieved_tflops(shape: &GemmShape, gpu: &GpuSpec) -> f64 {
    shape.flops() / (gemm_time_us(shape, gpu) * 1e-6) / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::platform::Platform;

    fn a100() -> GpuSpec {
        Platform::perlmutter().gpu
    }

    #[test]
    fn monotone_in_flops_roughly() {
        let g = a100();
        let small = gemm_time_us(&GemmShape::new(1024, 1024, 1024), &g);
        let large = gemm_time_us(&GemmShape::new(4096, 4096, 4096), &g);
        assert!(large > 10.0 * small, "small={small} large={large}");
    }

    #[test]
    fn large_gemm_near_roofline() {
        let g = a100();
        let t = achieved_tflops(&GemmShape::new(8192, 8192, 8192), &g);
        // big square fp16 GEMMs: 40-65% of peak (training-calibrated)
        assert!(t > 0.40 * g.peak_tflops_fp16, "achieved {t}");
        assert!(t < 0.70 * g.peak_tflops_fp16, "achieved {t}");
    }

    #[test]
    fn tiny_gemm_is_launch_dominated() {
        let g = a100();
        let t = gemm_time_us(&GemmShape::new(32, 32, 32), &g);
        assert!(t < 2.5 * g.launch_us, "{t}");
    }

    #[test]
    fn skinny_gemm_is_memory_bound() {
        let g = a100();
        let shape = GemmShape::new(8192, 64, 8192); // low arithmetic intensity
        let t_us = gemm_time_us(&shape, &g);
        let t_mem_us = shape.bytes() / (g.mem_bw_gbs * 1e9) * 1e6;
        assert!((t_us - g.launch_us - t_mem_us).abs() / t_mem_us < 0.05);
    }

    #[test]
    fn kernel_selection_creates_steps() {
        // Scanning m across a tile boundary must produce a visible
        // efficiency discontinuity (the phenomenon regressors must learn).
        let g = a100();
        let per_row = |m: usize| {
            gemm_time_us(&GemmShape::new(m, 4096, 4096), &g) / m as f64
        };
        // per-row cost right above a 128 boundary jumps vs right below
        let below = per_row(1280);
        let above = per_row(1281);
        assert!(above > below, "below={below} above={above}");
    }

    #[test]
    fn batched_gemm_fills_waves() {
        let g = a100();
        // One l=2048 attention head-product vs 64 of them: the batch fills
        // the machine, so per-instance time drops.
        let single = gemm_time_us(&GemmShape::batched(1, 2048, 96, 2048), &g);
        let batch = gemm_time_us(&GemmShape::batched(64, 2048, 96, 2048), &g);
        assert!(batch < 64.0 * single, "batch={batch} single={single}");
    }

    #[test]
    fn gh200_faster_than_a100() {
        let h = Platform::vista().gpu;
        let g = a100();
        let s = GemmShape::new(4096, 4096, 4096);
        assert!(gemm_time_us(&s, &h) < gemm_time_us(&s, &g));
    }

    #[test]
    fn tile_selection_prefers_big_tiles_for_big_problems() {
        let g = a100();
        let (tm, _, _) = select_tile(&GemmShape::new(8192, 8192, 8192), &g);
        assert!(tm >= 128);
        let (tm2, tn2, _) = select_tile(&GemmShape::new(64, 64, 4096), &g);
        assert!(tm2 <= 128 && tn2 <= 128);
    }
}
