//! GPU compute-latency models: the deterministic "structure" of the
//! simulated testbeds (jitter is applied on top by `sim`).
//!
//! These models intentionally exhibit the phenomena the paper argues make
//! purely analytical prediction hard (Challenge 1-2): discontinuous
//! auto-tuned kernel selection, tile/wave quantization, and cache-regime
//! bandwidth cliffs. The *regressors* must learn these surfaces from
//! samples; the closed-form `baselines::analytical` model deliberately
//! ignores them — reproducing the paper's "who wins" comparison.

pub mod gemm;
pub mod memops;

pub use gemm::{gemm_time_us, GemmShape};
pub use memops::{membound_time_us, MemOpKind};
