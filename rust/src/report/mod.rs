//! Report generation: the markdown tables and figure series that
//! regenerate every experimental artifact of the paper (Tables VIII-IX,
//! Figures 2-3), written to reports/ and printed to stdout.

pub mod tables;
pub mod figures;

pub use figures::{fig2_markdown, fig3_markdown};
pub use tables::{markdown_table, table8_markdown, table9_markdown, PAPER_CONFIGS};

use std::path::Path;

/// Write a report file under reports/ (best-effort) and return the text.
pub fn emit(name: &str, text: &str) -> String {
    let dir = Path::new("reports");
    if std::fs::create_dir_all(dir).is_ok() {
        let _ = std::fs::write(dir.join(name), text);
    }
    text.to_string()
}
