//! Table VIII (performance stability), Table IX (component-level
//! prediction errors), and the pipeline-schedule comparison generators.

use crate::config::{ModelCfg, ParallelCfg, Platform};
use crate::net::topology::{ClusterTopology, RankMap, TrafficVolumes};
use crate::pipeline::{Executor, ScheduleError, ScheduleKind, TaskTimes};
use crate::predictor::errors::ComponentErrors;
use crate::predictor::registry::BatchPredictor;
use crate::predictor::{evaluate, predict};
use crate::trainrun::{stability, stage_plans, try_run_batch_with_plans_exec, BatchTrace};
use crate::util::stats;

/// The five evaluation configurations of Tables VIII/IX:
/// (model preset name, Pipeline-Model-Data).
pub const PAPER_CONFIGS: [(&str, &str); 5] = [
    ("gpt20b", "4-4-8"),
    ("gpt20b", "4-8-4"),
    ("gpt20b", "8-4-4"),
    ("llama13b", "4-8-2"),
    ("llemma7b", "4-2-2"),
];

pub fn paper_configs() -> Vec<(ModelCfg, ParallelCfg)> {
    PAPER_CONFIGS
        .iter()
        .map(|(m, p)| {
            (ModelCfg::by_name(m).unwrap(), ParallelCfg::parse(p).unwrap())
        })
        .collect()
}

/// The ranked `fgpm sweep` table: one `(strategy label, predicted batch
/// seconds, GiB/GPU)` row per feasible configuration, fastest first,
/// plus the skip-reason footers. BOTH the local engine path and the
/// `sweep --remote` thin client render through this function, so a
/// remote sweep's table is byte-identical to a local run on the same
/// spec (property-tested in `tests/remote_sweep.rs`).
pub fn sweep_table_text(
    title: &str,
    rows: &[(String, f64, f64)],
    skipped_oom: usize,
    skipped_sched: usize,
    skipped_microbatch: usize,
    hbm_gib: f64,
) -> String {
    let mut s = format!("{title}\n");
    for (i, (label, seconds, mem_gib)) in rows.iter().enumerate() {
        s.push_str(&format!(
            "{:>2}. {:<9} {:>8.2} s   {:>5.1} GiB/GPU{}\n",
            i + 1,
            label,
            seconds,
            mem_gib,
            if i == 0 { "   <- best" } else { "" }
        ));
    }
    if skipped_oom > 0 {
        s.push_str(&format!(
            "({skipped_oom} strategies skipped: exceed {hbm_gib} GiB HBM)\n"
        ));
    }
    if skipped_sched > 0 {
        s.push_str(&format!(
            "({skipped_sched} strategies skipped: schedule rejects geometry)\n"
        ));
    }
    if skipped_microbatch > 0 {
        s.push_str(&format!(
            "({skipped_microbatch} strategies skipped: too few micro-batches for pipeline depth)\n"
        ));
    }
    s
}

/// The ranked `fgpm serve-plan` table: one row per feasible serving
/// deployment `(tp x replicas, max-batch)`, SLO-compliant configs first
/// (then p99 ascending), with the simulated token throughput, token
/// latency percentiles, and the quasi-static QPS capacity. A `!SLO`
/// marker flags rows whose simulated p99 token latency exceeds the SLO
/// at the offered load, and the OOM footer mirrors `sweep_table_text`.
pub fn serve_plan_table_text(
    title: &str,
    report: &crate::sweep::ServePlanReport,
    hbm_gib: f64,
) -> String {
    let mut s = format!("{title}\n");
    for (i, row) in report.rows.iter().enumerate() {
        s.push_str(&format!(
            "{:>2}. {:<12} {:>8.0} tok/s   p50 {:>7.1} ms  p99 {:>7.1} ms   cap {:>6.2} qps   {:>5.1} GiB/GPU  <= {:>4} seqs{}{}\n",
            i + 1,
            row.cand.label(),
            row.tokens_per_sec,
            row.p50_ms,
            row.p99_ms,
            row.qps_capacity,
            row.mem_gib,
            row.max_seqs,
            if row.compliant { "" } else { "   !SLO" },
            if i == 0 && row.compliant { "   <- best" } else { "" }
        ));
    }
    if report.rows.is_empty() {
        s.push_str("(no feasible serving configuration)\n");
    }
    if report.skipped_oom > 0 {
        s.push_str(&format!(
            "({} configs skipped: KV cache + weights exceed {hbm_gib} GiB HBM)\n",
            report.skipped_oom
        ));
    }
    s
}

/// The fault-mode sweep table: the plain ranked rows plus the closed-form
/// goodput columns. Row tuples are `(label, seconds, mem_gib,
/// goodput_frac, useful_flop_frac, ckpt_overhead_frac)` — the same shape
/// the coordinator's fault-mode row JSON carries, so the local engine
/// path and `sweep --remote` render byte-identically.
pub fn goodput_sweep_table_text(
    title: &str,
    rows: &[(String, f64, f64, f64, f64, f64)],
    skipped_oom: usize,
    skipped_sched: usize,
    skipped_microbatch: usize,
    hbm_gib: f64,
) -> String {
    let mut s = format!("{title}\n");
    for (i, (label, seconds, mem_gib, goodput, useful, ckpt)) in rows.iter().enumerate() {
        s.push_str(&format!(
            "{:>2}. {:<9} {:>8.2} s   {:>5.1} GiB/GPU   good {:>5.1}%  useful {:>5.1}%  ckpt {:>4.1}%{}\n",
            i + 1,
            label,
            seconds,
            mem_gib,
            goodput * 100.0,
            useful * 100.0,
            ckpt * 100.0,
            if i == 0 { "   <- best" } else { "" }
        ));
    }
    if skipped_oom > 0 {
        s.push_str(&format!(
            "({skipped_oom} strategies skipped: exceed {hbm_gib} GiB HBM)\n"
        ));
    }
    if skipped_sched > 0 {
        s.push_str(&format!(
            "({skipped_sched} strategies skipped: schedule rejects geometry)\n"
        ));
    }
    if skipped_microbatch > 0 {
        s.push_str(&format!(
            "({skipped_microbatch} strategies skipped: too few micro-batches for pipeline depth)\n"
        ));
    }
    s
}

/// The `fgpm explain` / `predict --explain` attribution table: one row
/// per (component, op class, direction, worst network tier) bucket of
/// the predicted step, with µs, % of step, and the comm µs hidden under
/// compute by overlap. The rows reconstruct the step time exactly (the
/// closed forms are linear in their components), so the footer's
/// `sum` line is a built-in self-check rather than an approximation.
pub fn explain_table_text(ledger: &crate::predictor::e2e::Ledger) -> String {
    let mut s = format!(
        "{} — predicted step {:.2} ms (critical-path stage {})\n",
        ledger.label,
        ledger.total_us / 1e3,
        ledger.critical_stage
    );
    s.push_str(&format!(
        "{:<18} {:<10} {:<4} {:<6} {:>12} {:>7} {:>12}\n",
        "component", "class", "dir", "tier", "µs", "% step", "overlap µs"
    ));
    for r in &ledger.rows {
        let pct = if ledger.total_us > 0.0 { r.us / ledger.total_us * 100.0 } else { 0.0 };
        s.push_str(&format!(
            "{:<18} {:<10} {:<4} {:<6} {:>12.1} {:>6.1}% {:>12.1}\n",
            r.component, r.class, r.dir, r.tier, r.us, pct, r.overlapped_us
        ));
    }
    let sum = ledger.rows_sum_us();
    s.push_str(&format!(
        "{:<18} {:<10} {:<4} {:<6} {:>12.1} {:>6.1}%\n",
        "sum", "", "", "", sum,
        if ledger.total_us > 0.0 { sum / ledger.total_us * 100.0 } else { 0.0 }
    ));
    s
}

/// The `fgpm goodput` grid: closed-form goodput fraction over checkpoint
/// interval (rows) × GPU MTBF (columns), with the per-column Young
/// optimum `√(2δ/λ)` annotated under the table and the best cell marked.
pub fn goodput_grid_text(
    title: &str,
    interval_steps: &[usize],
    mtbf_hours: &[f64],
    goodput: &[Vec<f64>],
    optimal_interval_s: &[f64],
) -> String {
    let mut s = format!("{title}\n");
    s.push_str(&format!("{:>12}", "ckpt every"));
    for m in mtbf_hours {
        s.push_str(&format!("  {:>11}", format!("mtbf {m:.0}h")));
    }
    s.push('\n');
    // best cell: max goodput, first (shortest interval, smallest mtbf) on ties
    let mut best = (0usize, 0usize, f64::NEG_INFINITY);
    for (i, row) in goodput.iter().enumerate() {
        for (j, &g) in row.iter().enumerate() {
            if g.total_cmp(&best.2).is_gt() {
                best = (i, j, g);
            }
        }
    }
    for (i, (&steps, row)) in interval_steps.iter().zip(goodput).enumerate() {
        s.push_str(&format!("{:>12}", format!("{steps} steps")));
        for (j, &g) in row.iter().enumerate() {
            let mark = if (i, j) == (best.0, best.1) { '*' } else { ' ' };
            s.push_str(&format!("  {:>10.2}%{mark}", g * 100.0));
        }
        s.push('\n');
    }
    s.push_str(&format!("{:>12}", "Young opt"));
    for &t in optimal_interval_s {
        let cell = if t.is_finite() { format!("{t:.0} s") } else { "∞".to_string() };
        s.push_str(&format!("  {cell:>11}"));
    }
    s.push_str("\n(* best closed-form goodput; Young opt = √(2·ckpt_write/λ) wall-clock interval)\n");
    s
}

/// Generic markdown table.
pub fn markdown_table(headers: &[String], rows: &[Vec<String>]) -> String {
    let mut s = format!("| {} |\n", headers.join(" | "));
    s.push_str(&format!("|{}\n", "---|".repeat(headers.len())));
    for r in rows {
        s.push_str(&format!("| {} |\n", r.join(" | ")));
    }
    s
}

/// Table VIII: training-batch time statistics (min/max/avg + %increase)
/// for the five configs on both platforms.
pub fn table8_markdown(n_batches: usize, seed: u64) -> String {
    let platforms = [Platform::perlmutter(), Platform::vista()];
    let mut headers = vec!["Training Batch".to_string()];
    for (m, p) in PAPER_CONFIGS {
        for plat in ["P", "V"] {
            headers.push(format!("{m}({p}) {plat}"));
        }
    }
    let mut rows: Vec<Vec<String>> = vec![
        vec!["Minimum".into()],
        vec!["Maximum".into()],
        vec!["Average".into()],
        vec!["% Increase of Avg to Min".into()],
    ];
    for (model, par) in paper_configs() {
        for platform in &platforms {
            let st = stability(&model, &par, platform, n_batches, seed);
            rows[0].push(format!("{:.2}", st.min_s));
            rows[1].push(format!("{:.2}", st.max_s));
            rows[2].push(format!("{:.2}", st.avg_s));
            rows[3].push(format!("{:.2}%", st.pct_increase));
        }
    }
    format!(
        "# Table VIII — Training batch time statistics (s), {n_batches} batches/config\n\n{}",
        markdown_table(&headers, &rows)
    )
}

/// Pipeline-schedule comparison for one configuration: event-accurate
/// simulated total (fastest of `n_batches`), the schedule's closed form
/// fed with the measured max stage times and per-crossing P2P, the worst
/// per-stage bubble fraction, and the measured communication exposure
/// (makespan minus the zero-P2P counterfactual). 1F1B and GPipe share a
/// closed form; interleaving shrinks the bubble but pays `v`× the
/// crossings; ZB-H1 fills the cool-down with deferred weight grads.
pub fn schedule_compare_markdown(
    model: &ModelCfg,
    par: &ParallelCfg,
    platform: &Platform,
    interleave_chunks: usize,
    n_batches: usize,
    seed: u64,
) -> Result<String, ScheduleError> {
    let m = model.iters_per_update;
    let n_batches = n_batches.max(1);
    // Predicted column: all valid schedules evaluated through the sweep
    // engine's shared op cache in one pass (their op sets are identical,
    // so every schedule after the first composes entirely from cache).
    let valid: Vec<ParallelCfg> = ScheduleKind::all(interleave_chunks)
        .into_iter()
        .filter(|k| k.build().validate(par.pp, m).is_ok())
        .map(|k| par.with_schedule(k))
        .collect();
    let engine = crate::sweep::Engine::new();
    let mut oracle = crate::predictor::e2e::OraclePredictor { platform: platform.clone() };
    // a worker panic degrades the predicted column to "—" instead of
    // failing the whole comparison (the simulated columns stand alone)
    let predicted: std::collections::HashMap<ScheduleKind, f64> = engine
        .evaluate(model, platform, &valid, &mut oracle)
        .map(|rows| {
            rows.into_iter().map(|row| (row.par.schedule, row.prediction.total_us)).collect()
        })
        .unwrap_or_default();
    // one executor across every schedule's batches and counterfactuals
    let mut exec = Executor::new();
    let mut rows = Vec::new();
    for kind in ScheduleKind::all(interleave_chunks) {
        let cfg = par.with_schedule(kind);
        if let Err(e) = kind.build().validate(cfg.pp, m) {
            // keep the comparable rows; report why this one is absent
            rows.push(vec![
                kind.label(),
                "—".into(),
                "—".into(),
                "—".into(),
                "—".into(),
                "—".into(),
                format!("unavailable: {e}"),
            ]);
            continue;
        }
        let plans = stage_plans(model, &cfg, platform);
        let mut best: Option<BatchTrace> = None;
        for i in 0..n_batches {
            let tr =
                try_run_batch_with_plans_exec(model, &cfg, &plans, platform, seed + i as u64, &mut exec)?;
            if best.as_ref().is_none_or(|b| tr.total_us < b.total_us) {
                best = Some(tr);
            }
        }
        let tr = best.expect("n_batches >= 1");
        let max_fwd = tr.stage_fwd_us.iter().cloned().fold(0.0, f64::max);
        let max_bwd = tr.stage_bwd_us.iter().cloned().fold(0.0, f64::max);
        let closed = kind.closed_form_runtime_us(&crate::pipeline::ClosedFormInputs {
            micro_batches: m,
            stages: cfg.pp,
            max_fwd,
            max_bwd,
            p2p_us: tr.pp_p2p_us,
            p2p_overlap: cfg.p2p_overlap(),
            first_stage_sync: tr.dp_allreduce_first_us,
            max_update: tr.max_update_us,
        });
        // bubble fraction over a deterministic-shape schedule built from
        // the measured mean stage times and mean crossing time
        let times = TaskTimes::compute(
            tr.stage_fwd_us.iter().map(|&t| vec![t; m]).collect(),
            tr.stage_bwd_us.iter().map(|&t| vec![t; m]).collect(),
        )
        .with_uniform_sends(tr.pp_p2p_us)
        .with_overlap(cfg.p2p_overlap());
        let sched = exec.execute(kind.build().as_ref(), &times)?;
        let bubble = (0..cfg.pp).map(|s| sched.bubble_fraction(s)).fold(0.0, f64::max);
        exec.recycle(sched);
        rows.push(vec![
            kind.label(),
            format!("{:.2}", tr.total_us / 1e6),
            predicted
                .get(&kind)
                .map(|us| format!("{:.2}", us / 1e6))
                .unwrap_or_else(|| "—".into()),
            format!("{:.2}", closed / 1e6),
            format!("{:+.2}%", stats::rel_err_pct(closed, tr.total_us)),
            format!("{:.1}%", bubble * 100.0),
            format!("{:.3}", tr.p2p_exposed_us / 1e6),
        ]);
    }
    let headers: Vec<String> = [
        "Schedule",
        "Simulated (s)",
        "Predicted (s)",
        "Closed form (s)",
        "Closed-form err",
        "Max bubble",
        "P2P exposed (s)",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    Ok(format!(
        "# Pipeline schedules — {}({}) on {}, {} micro-batches, P2P overlap {:.0}%\n\n{}\n\
         Simulated = fastest of {n_batches} event-accurate batches; predicted = the\n\
         oracle-fed predictor composition via the sweep engine's shared op cache (all\n\
         schedules share one op set, so only the first pays backend calls); closed form\n\
         uses the measured max stage times plus the per-crossing P2P (1F1B and GPipe\n\
         share one closed form). \"P2P exposed\" is the simulated makespan minus the\n\
         same schedule with every transfer zeroed.\n",
        model.name,
        par.label(),
        platform.name,
        m,
        par.p2p_overlap() * 100.0,
        markdown_table(&headers, &rows)
    ))
}

/// Per-invocation transfer volumes of a (model, parallelism) pair, for
/// the byte columns of the group→tier traffic matrix: the MP all-reduce
/// moves `b·l·d` fp16 activations, the DP all-reduce the worst stage's
/// fp16 gradients, and each PP boundary `b·l·d/|mp|` fp16 activations
/// (Megatron scatter-gather).
pub fn traffic_volumes(model: &ModelCfg, par: &ParallelCfg) -> TrafficVolumes {
    use crate::ops::params::{stage_params_paper, StageRole};
    use crate::pipeline::encoder_allocation;
    let bld = (model.micro_batch * model.l * model.d) as f64 * 2.0; // fp16
    let vocab = crate::ops::params::padded_vocab(model.vocab, par.mp);
    let alloc = encoder_allocation(model.encoders, par.pp);
    let max_params = alloc
        .iter()
        .enumerate()
        .map(|(s, &n_enc)| {
            stage_params_paper(StageRole::of(s, par.pp), n_enc, model.d, vocab, par.mp)
        })
        .fold(0.0, f64::max);
    TrafficVolumes {
        mp_ring_bytes: TrafficVolumes::ring_link_bytes(par.mp, bld),
        dp_ring_bytes: TrafficVolumes::ring_link_bytes(par.dp, max_params * 2.0),
        pp_bytes: bld / par.mp as f64,
    }
}

/// `fgpm topo`: cluster tiers, group geometries under the rank map, the
/// group→tier traffic matrix (crossing counts AND per-tier bytes for the
/// model's transfer volumes), and every pipeline boundary's resolved
/// path (the wrap-around hop included) with its per-hop time for a
/// reference payload.
pub fn topo_markdown(
    model: &ModelCfg,
    par: &ParallelCfg,
    platform: &Platform,
    payload_mb: f64,
) -> String {
    use crate::net::topology::p2p_path_time_us;
    let topo = ClusterTopology::of(platform);
    let map = RankMap::new(par, platform);
    let bytes = payload_mb * 1e6;

    let tier_rows: Vec<Vec<String>> = topo
        .tier_rows()
        .into_iter()
        .map(|(name, bw, lat, cap)| {
            vec![
                name.to_string(),
                format!("{bw:.0}"),
                format!("{lat:.1}"),
                if cap.is_finite() { format!("{cap:.0}") } else { "∞".to_string() },
            ]
        })
        .collect();
    let tiers = markdown_table(
        &["tier".into(), "GB/s".into(), "lat µs".into(), "flows/link".into()],
        &tier_rows,
    );

    let gib = |b: f64| {
        if b == 0.0 {
            "0".to_string()
        } else {
            format!("{:.3}", b / (1024.0 * 1024.0 * 1024.0))
        }
    };
    let vols = traffic_volumes(model, par);
    let traffic_rows: Vec<Vec<String>> = map
        .traffic_matrix_with(&vols)
        .into_iter()
        .map(|r| {
            vec![
                r.kind,
                r.intra.to_string(),
                r.rail.to_string(),
                r.spine.to_string(),
                gib(r.intra_bytes),
                gib(r.rail_bytes),
                gib(r.spine_bytes),
            ]
        })
        .collect();
    let traffic = markdown_table(
        &[
            "group traffic".into(),
            "intra".into(),
            "rail".into(),
            "spine".into(),
            "intra GiB".into(),
            "rail GiB".into(),
            "spine GiB".into(),
        ],
        &traffic_rows,
    );

    let mut s = format!(
        "# Topology — {} ({}) under rank map `{}`, topo `{}`\n\n\
         MP group: {:?} fabric {} · DP group: {:?} fabric {}\n\n{tiers}\n{traffic}",
        platform.name,
        par.label(),
        par.rank_order.label(),
        platform.topo.label(),
        map.mp_geom(),
        map.mp_fabric().describe(),
        map.dp_geom(),
        map.dp_fabric().describe(),
    );
    if par.pp > 1 {
        s.push('\n');
        let mut rows = Vec::new();
        for (st, path) in map.pp_fwd_paths().iter().enumerate() {
            let to = (st + 1) % par.pp;
            let label = if to == (st + 1) { format!("stage {st} → {to}") } else { format!("stage {st} → {to} (wrap)") };
            rows.push(vec![
                label,
                path.describe(),
                format!("{:.1}", p2p_path_time_us(bytes, path, platform.gpu.launch_us)),
            ]);
        }
        s.push_str(&markdown_table(
            &["PP boundary (fwd)".into(), "path".into(), format!("µs @ {payload_mb:.0} MB")],
            &rows,
        ));
    }
    s
}

/// Table IX over one platform given a ready BatchPredictor.
pub fn table9_errors(
    platform: &Platform,
    predictor: &mut dyn BatchPredictor,
    n_batches: usize,
    seed: u64,
) -> Vec<ComponentErrors> {
    paper_configs()
        .into_iter()
        .map(|(model, par)| {
            let cp = predict(&model, &par, platform, predictor);
            evaluate(&model, &par, platform, &cp, n_batches, seed)
        })
        .collect()
}

/// Render the Table IX markdown for (platform -> per-config errors).
pub fn table9_markdown(results: &[(String, Vec<ComponentErrors>)]) -> String {
    let mut headers = vec!["Component".to_string()];
    for (plat, errs) in results {
        let letter = if plat.starts_with('p') || plat.starts_with('P') { "P" } else { "V" };
        for e in errs {
            headers.push(format!("{} {letter}", e.label));
        }
    }
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (ci, name) in ComponentErrors::COMPONENT_NAMES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        for (_plat, errs) in results {
            for e in errs {
                row.push(format!("{:+.2}%", e.values()[ci]));
            }
        }
        rows.push(row);
    }
    // summary: mean |overall| per platform
    let mut summary = String::new();
    for (plat, errs) in results {
        let overall: Vec<f64> = errs.iter().map(|e| e.overall.abs()).collect();
        summary.push_str(&format!(
            "- mean |overall error| on {}: **{:.2}%** (paper: 4.98% P / 9.38% V)\n",
            plat,
            stats::mean(&overall)
        ));
    }
    format!(
        "# Table IX — Component-level prediction errors (fastest measured batch)\n\n{}\n{}",
        markdown_table(&headers, &rows),
        summary
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::e2e::OraclePredictor;

    #[test]
    fn paper_configs_resolve() {
        let c = paper_configs();
        assert_eq!(c.len(), 5);
        assert_eq!(c[0].1.gpus(), 128);
        assert_eq!(c[4].1.gpus(), 16);
    }

    #[test]
    fn markdown_table_shape() {
        let t = markdown_table(
            &["a".into(), "b".into()],
            &[vec!["1".into(), "2".into()]],
        );
        assert_eq!(t.lines().count(), 3);
        assert!(t.contains("| 1 | 2 |"));
    }

    #[test]
    fn schedule_compare_has_four_distinct_rows_and_exposure() {
        let md = schedule_compare_markdown(
            &ModelCfg::llemma7b(),
            &ParallelCfg::new(4, 2, 2),
            &Platform::perlmutter(),
            2,
            1,
            5,
        )
        .unwrap();
        assert!(md.contains("| 1f1b |"));
        assert!(md.contains("| gpipe |"));
        assert!(md.contains("| interleaved:2 |"));
        assert!(md.contains("| zb-h1 |"));
        assert!(md.contains("P2P exposed"));
        // the four simulated totals must not all collapse to one value
        let totals: Vec<&str> = md
            .lines()
            .filter(|l| {
                l.starts_with("| 1f1b")
                    || l.starts_with("| gpipe")
                    || l.starts_with("| interleaved")
                    || l.starts_with("| zb-h1")
            })
            .map(|l| l.split('|').nth(2).unwrap().trim())
            .collect();
        assert_eq!(totals.len(), 4);
        assert!(
            totals.iter().collect::<std::collections::HashSet<_>>().len() >= 2,
            "totals all identical: {totals:?}"
        );
    }

    #[test]
    fn schedule_compare_keeps_valid_rows_when_one_schedule_rejects() {
        // 6 micro-batches over 4 stages: interleaving is impossible, but
        // the 1F1B and GPipe rows must still be produced, with the
        // interleaved row explaining its absence.
        let mut model = ModelCfg::llemma7b();
        model.iters_per_update = 6; // 6 % 4 != 0
        let md = schedule_compare_markdown(
            &model,
            &ParallelCfg::new(4, 2, 2),
            &Platform::perlmutter(),
            2,
            1,
            5,
        )
        .unwrap();
        assert!(md.contains("| 1f1b |"));
        assert!(md.contains("| gpipe |"));
        assert!(md.contains("unavailable:"), "{md}");
    }

    #[test]
    fn topo_markdown_renders_matrix_and_wrap() {
        let model = ModelCfg::gpt20b();
        let md = topo_markdown(
            &model,
            &ParallelCfg::parse("4-4-8").unwrap(),
            &Platform::perlmutter(),
            25.0,
        );
        assert!(md.contains("MP all-reduce ring"), "{md}");
        assert!(md.contains("PP wrap-around"), "{md}");
        assert!(md.contains("(wrap)"), "{md}");
        assert!(md.contains("rail"), "{md}");
        assert!(md.contains("tp-first"), "{md}");
        assert!(md.contains("intra GiB"), "{md}");
        // mp=4 on one node: the MP ring's bytes land on the intra tier —
        // 4 pairs x 1.5 x (b·l·d fp16) = 4 x 1.5 x 0.09375 GiB
        assert!(md.contains("| MP all-reduce ring | 4 | 0 | 0 | 0.562 | 0 | 0 |"), "{md}");
        // dp-first flips the MP fabric onto the rail tier
        let dpf = topo_markdown(
            &model,
            &ParallelCfg::parse("4-4-8@dp-first").unwrap(),
            &Platform::perlmutter(),
            25.0,
        );
        assert!(dpf.contains("dp-first"), "{dpf}");
        assert!(dpf.contains("MP group: CommGeom { nodes: 4"), "{dpf}");
    }

    #[test]
    fn traffic_volumes_match_table_i_shapes() {
        let model = ModelCfg::gpt20b();
        let par = ParallelCfg::parse("4-4-8").unwrap();
        let v = traffic_volumes(&model, &par);
        let bld = (4 * 2048 * 6144) as f64 * 2.0;
        assert_eq!(v.mp_ring_bytes, 1.5 * bld); // 2·(4-1)/4
        assert_eq!(v.pp_bytes, bld / 4.0);
        assert!(v.dp_ring_bytes > 0.0);
        // single-member groups carry nothing
        let solo = traffic_volumes(&model, &ParallelCfg::new(4, 1, 1));
        assert_eq!(solo.mp_ring_bytes, 0.0);
        assert_eq!(solo.dp_ring_bytes, 0.0);
    }

    #[test]
    fn sweep_table_text_shape() {
        let rows = vec![
            ("2-2-4".to_string(), 12.3456, 5.67),
            ("4-2-2/gpipe".to_string(), 13.0, 6.0),
        ];
        let t = sweep_table_text("demo — predicted batch seconds:", &rows, 2, 1, 0, 40.0);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[0], "demo — predicted batch seconds:");
        assert!(lines[1].starts_with(" 1. 2-2-4"));
        assert!(lines[1].ends_with("<- best"));
        assert!(lines[1].contains("12.35 s"), "{}", lines[1]);
        assert!(!lines[2].contains("best"));
        assert_eq!(lines[3], "(2 strategies skipped: exceed 40 GiB HBM)");
        assert_eq!(lines[4], "(1 strategies skipped: schedule rejects geometry)");
        // skip footers vanish when nothing was skipped
        let t0 = sweep_table_text("t", &rows, 0, 0, 0, 40.0);
        assert_eq!(t0.lines().count(), 3);
        // the new micro-batch footer is invisible at zero, visible above it
        let tm = sweep_table_text("t", &rows, 0, 0, 3, 40.0);
        assert_eq!(tm.lines().count(), 4);
        assert_eq!(
            tm.lines().last().unwrap(),
            "(3 strategies skipped: too few micro-batches for pipeline depth)"
        );
    }

    #[test]
    fn goodput_sweep_table_text_shape() {
        let rows = vec![
            ("2-2-4".to_string(), 12.3456, 5.67, 0.934, 0.801, 0.021),
            ("4-2-2".to_string(), 13.0, 6.0, 0.91, 0.78, 0.03),
        ];
        let t = goodput_sweep_table_text("demo:", &rows, 0, 0, 2, 40.0);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[1].contains("good  93.4%"), "{}", lines[1]);
        assert!(lines[1].contains("useful  80.1%"), "{}", lines[1]);
        assert!(lines[1].contains("ckpt  2.1%"), "{}", lines[1]);
        assert!(lines[1].ends_with("<- best"));
        assert_eq!(
            lines[3],
            "(2 strategies skipped: too few micro-batches for pipeline depth)"
        );
    }

    #[test]
    fn explain_table_renders_rows_and_exact_sum_footer() {
        let ledger = crate::predictor::e2e::explain(
            &ModelCfg::llemma7b(),
            &ParallelCfg::new(4, 2, 2),
            &Platform::perlmutter(),
            &mut OraclePredictor { platform: Platform::perlmutter() },
        );
        let t = explain_table_text(&ledger);
        assert!(t.contains("critical-path stage"), "{t}");
        assert!(t.contains("pipeline-compute"), "{t}");
        assert!(t.contains("gemm"), "{t}");
        assert!(t.lines().next().unwrap().contains("predicted step"), "{t}");
        // the footer's sum reconstructs the step within display precision
        let sum_line = t.lines().last().unwrap();
        assert!(sum_line.starts_with("sum"), "{t}");
        assert!(sum_line.contains("100.0%"), "{t}");
        // every body row carries all seven columns
        for l in t.lines().skip(2) {
            assert!(l.split_whitespace().count() >= 6, "{l}");
        }
    }

    #[test]
    fn goodput_grid_text_marks_best_cell_and_young_optimum() {
        let t = goodput_grid_text(
            "goodput grid:",
            &[16, 64],
            &[10_000.0, 40_000.0],
            &[vec![0.90, 0.95], vec![0.88, 0.97]],
            &[1200.0, f64::INFINITY],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 6, "{t}");
        assert!(lines[1].contains("mtbf 10000h"), "{t}");
        // exactly one best-cell marker, on the 0.97 cell
        assert_eq!(t.matches('%').count(), 4, "{t}"); // one per grid cell
        assert_eq!(t.matches("%*").count(), 1, "{t}");
        assert!(lines[3].contains("97.00%*"), "{t}");
        assert!(lines[4].contains("1200 s"), "{t}");
        assert!(lines[4].contains('∞'), "{t}");
    }

    #[test]
    fn table9_markdown_renders() {
        let p = Platform::perlmutter();
        let mut oracle = OraclePredictor { platform: p.clone() };
        // only the cheapest config to keep the unit test fast
        let model = ModelCfg::llemma7b();
        let par = ParallelCfg::new(4, 2, 2);
        let cp = predict(&model, &par, &p, &mut oracle);
        let e = evaluate(&model, &par, &p, &cp, 2, 1);
        let md = table9_markdown(&[("perlmutter".into(), vec![e])]);
        assert!(md.contains("Encoder_Fwd"));
        assert!(md.contains("Overall"));
        assert!(md.contains("mean |overall error|"));
    }
}
