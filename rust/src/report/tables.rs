//! Table VIII (performance stability) and Table IX (component-level
//! prediction errors) generators.

use crate::config::{ModelCfg, ParallelCfg, Platform};
use crate::predictor::errors::ComponentErrors;
use crate::predictor::registry::BatchPredictor;
use crate::predictor::{evaluate, predict};
use crate::trainrun::stability;
use crate::util::stats;

/// The five evaluation configurations of Tables VIII/IX:
/// (model preset name, Pipeline-Model-Data).
pub const PAPER_CONFIGS: [(&str, &str); 5] = [
    ("gpt20b", "4-4-8"),
    ("gpt20b", "4-8-4"),
    ("gpt20b", "8-4-4"),
    ("llama13b", "4-8-2"),
    ("llemma7b", "4-2-2"),
];

pub fn paper_configs() -> Vec<(ModelCfg, ParallelCfg)> {
    PAPER_CONFIGS
        .iter()
        .map(|(m, p)| {
            (ModelCfg::by_name(m).unwrap(), ParallelCfg::parse(p).unwrap())
        })
        .collect()
}

/// Generic markdown table.
pub fn markdown_table(headers: &[String], rows: &[Vec<String>]) -> String {
    let mut s = format!("| {} |\n", headers.join(" | "));
    s.push_str(&format!("|{}\n", "---|".repeat(headers.len())));
    for r in rows {
        s.push_str(&format!("| {} |\n", r.join(" | ")));
    }
    s
}

/// Table VIII: training-batch time statistics (min/max/avg + %increase)
/// for the five configs on both platforms.
pub fn table8_markdown(n_batches: usize, seed: u64) -> String {
    let platforms = [Platform::perlmutter(), Platform::vista()];
    let mut headers = vec!["Training Batch".to_string()];
    for (m, p) in PAPER_CONFIGS {
        for plat in ["P", "V"] {
            headers.push(format!("{m}({p}) {plat}"));
        }
    }
    let mut rows: Vec<Vec<String>> = vec![
        vec!["Minimum".into()],
        vec!["Maximum".into()],
        vec!["Average".into()],
        vec!["% Increase of Avg to Min".into()],
    ];
    for (model, par) in paper_configs() {
        for platform in &platforms {
            let st = stability(&model, &par, platform, n_batches, seed);
            rows[0].push(format!("{:.2}", st.min_s));
            rows[1].push(format!("{:.2}", st.max_s));
            rows[2].push(format!("{:.2}", st.avg_s));
            rows[3].push(format!("{:.2}%", st.pct_increase));
        }
    }
    format!(
        "# Table VIII — Training batch time statistics (s), {n_batches} batches/config\n\n{}",
        markdown_table(&headers, &rows)
    )
}

/// Table IX over one platform given a ready BatchPredictor.
pub fn table9_errors(
    platform: &Platform,
    predictor: &mut dyn BatchPredictor,
    n_batches: usize,
    seed: u64,
) -> Vec<ComponentErrors> {
    paper_configs()
        .into_iter()
        .map(|(model, par)| {
            let cp = predict(&model, &par, platform, predictor);
            evaluate(&model, &par, platform, &cp, n_batches, seed)
        })
        .collect()
}

/// Render the Table IX markdown for (platform -> per-config errors).
pub fn table9_markdown(results: &[(String, Vec<ComponentErrors>)]) -> String {
    let mut headers = vec!["Component".to_string()];
    for (plat, errs) in results {
        let letter = if plat.starts_with('p') || plat.starts_with('P') { "P" } else { "V" };
        for e in errs {
            headers.push(format!("{} {letter}", e.label));
        }
    }
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (ci, name) in ComponentErrors::COMPONENT_NAMES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        for (_plat, errs) in results {
            for e in errs {
                row.push(format!("{:+.2}%", e.values()[ci]));
            }
        }
        rows.push(row);
    }
    // summary: mean |overall| per platform
    let mut summary = String::new();
    for (plat, errs) in results {
        let overall: Vec<f64> = errs.iter().map(|e| e.overall.abs()).collect();
        summary.push_str(&format!(
            "- mean |overall error| on {}: **{:.2}%** (paper: 4.98% P / 9.38% V)\n",
            plat,
            stats::mean(&overall)
        ));
    }
    format!(
        "# Table IX — Component-level prediction errors (fastest measured batch)\n\n{}\n{}",
        markdown_table(&headers, &rows),
        summary
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::e2e::OraclePredictor;

    #[test]
    fn paper_configs_resolve() {
        let c = paper_configs();
        assert_eq!(c.len(), 5);
        assert_eq!(c[0].1.gpus(), 128);
        assert_eq!(c[4].1.gpus(), 16);
    }

    #[test]
    fn markdown_table_shape() {
        let t = markdown_table(
            &["a".into(), "b".into()],
            &[vec!["1".into(), "2".into()]],
        );
        assert_eq!(t.lines().count(), 3);
        assert!(t.contains("| 1 | 2 |"));
    }

    #[test]
    fn table9_markdown_renders() {
        let p = Platform::perlmutter();
        let mut oracle = OraclePredictor { platform: p.clone() };
        // only the cheapest config to keep the unit test fast
        let model = ModelCfg::llemma7b();
        let par = ParallelCfg::new(4, 2, 2);
        let cp = predict(&model, &par, &p, &mut oracle);
        let e = evaluate(&model, &par, &p, &cp, 2, 1);
        let md = table9_markdown(&[("perlmutter".into(), vec![e])]);
        assert!(md.contains("Encoder_Fwd"));
        assert!(md.contains("Overall"));
        assert!(md.contains("mean |overall error|"));
    }
}
