//! Figure 2 (pipeline schedule timelines) and Figure 3 (component
//! time-cost proportions) generators.

use crate::config::{ModelCfg, ParallelCfg, Platform};
use crate::pipeline::schedule::render_ascii_for;
use crate::pipeline::{ScheduleKind, TaskTimes};
use crate::predictor::e2e::ComponentPrediction;
use crate::predictor::predict;
use crate::predictor::registry::BatchPredictor;
use crate::report::tables::paper_configs;
use crate::trainrun::stage_plans;

/// Figure 2: canonical uniform-time timelines for all four pipeline
/// schedules (1F1B, GPipe, interleaved-1F1B, ZB-H1), plus a
/// measured-shape variant (under `par.schedule`) from an actual stage
/// plan with its real compute/P2P split.
pub fn fig2_markdown(model: &ModelCfg, par: &ParallelCfg, platform: &Platform) -> String {
    let mut s = String::from("# Figure 2 — pipeline schedule timelines\n\n");
    for kind in ScheduleKind::all(2) {
        // interleaving walks micro-batches in stage-sized groups, so the
        // canonical interleaved render uses 8 micro-batches over 4 stages
        let m = if matches!(kind, ScheduleKind::Interleaved1F1B { .. }) { 8 } else { 4 };
        let art = render_ascii_for(kind, &TaskTimes::uniform(4, m, 1.0, 2.0), 72)
            .expect("canonical geometry is valid for every schedule");
        s.push_str(&format!(
            "Canonical `{}` — 4 stages x {m} micro-batches (uniform times):\n\n```\n{art}```\n\n",
            kind.label()
        ));
    }

    let plans = stage_plans(model, par, platform);
    let sim = crate::sim::ClusterSim::new(platform.clone(), 1);
    let p2p_det = plans[0]
        .pp_send_fwd
        .as_ref()
        .map_or(0.0, |op| sim.deterministic_us(&op.lowered));
    let times = TaskTimes::compute(
        plans
            .iter()
            .map(|p| {
                vec![
                    p.fwd_ops.iter().map(|o| sim.deterministic_us(&o.lowered)).sum::<f64>();
                    model.iters_per_update
                ]
            })
            .collect(),
        plans
            .iter()
            .map(|p| {
                vec![
                    p.bwd_ops.iter().map(|o| sim.deterministic_us(&o.lowered)).sum::<f64>();
                    model.iters_per_update
                ]
            })
            .collect(),
    )
    .with_uniform_sends(p2p_det)
    .with_overlap(par.p2p_overlap());
    match render_ascii_for(par.schedule, &times, 100) {
        Ok(art) => s.push_str(&format!(
            "{}({}) on {} — `{}`, deterministic stage times, {} micro-batches:\n\n```\n{art}```\n",
            model.name,
            par.label(),
            platform.name,
            par.schedule.label(),
            model.iters_per_update,
        )),
        Err(e) => s.push_str(&format!(
            "{}({}) on {}: schedule `{}` unavailable for this geometry — {e}\n",
            model.name,
            par.label(),
            platform.name,
            par.schedule.label(),
        )),
    }
    s
}

/// One config's component proportions (% of predicted total). As in the
/// paper, proportions deliberately exceed 100% in sum: only Stage_Fwd,
/// Stage_Bwd, DP_Allreduce and Update are mutually exclusive phases;
/// encoder/MP/P2P shares are *within* the stage phases. The predictor
/// now keeps stage compute and PP P2P split, so the stage shares re-fold
/// one crossing per direction here to preserve the paper's Figure-3
/// accounting (where P2P was billed inside the sender's stage time).
#[derive(Clone, Debug)]
pub struct Proportions {
    pub label: String,
    pub stage_fwd: f64,
    pub stage_bwd: f64,
    pub dp_allreduce: f64,
    pub update: f64,
    pub encoder_fwd: f64,
    pub encoder_bwd: f64,
    pub mp_allreduce: f64,
    pub pp_p2p: f64,
}

pub fn proportions(cp: &ComponentPrediction, model: &ModelCfg, par: &ParallelCfg) -> Proportions {
    let m = model.iters_per_update as f64;
    let s = par.pp as f64;
    let pipeline_factor = m - 1.0 + s;
    let total = cp.total_us;
    let enc_per_stage = (model.encoders as f64 / par.pp as f64).ceil();
    let syncs = (model.encoder_fwd_syncs + model.encoder_bwd_syncs) as f64;
    Proportions {
        label: cp.label.clone(),
        stage_fwd: pipeline_factor * (cp.stage_fwd_max() + cp.pp_p2p_us) / total * 100.0,
        stage_bwd: pipeline_factor * (cp.stage_bwd_max() + cp.pp_p2p_us) / total * 100.0,
        dp_allreduce: cp.dp_allreduce_first_us / total * 100.0,
        update: cp.max_update_us / total * 100.0,
        encoder_fwd: m * enc_per_stage * cp.encoder_fwd_us / total * 100.0,
        encoder_bwd: m * enc_per_stage * cp.encoder_bwd_us / total * 100.0,
        mp_allreduce: m * enc_per_stage * syncs * cp.mp_allreduce_us / total * 100.0,
        pp_p2p: 2.0 * m * cp.pp_p2p_us / total * 100.0,
    }
}

/// Figure 3: the proportion series for all five configs on one platform.
pub fn fig3_markdown(platform: &Platform, predictor: &mut dyn BatchPredictor) -> String {
    let mut rows = Vec::new();
    for (model, par) in paper_configs() {
        let cp = predict(&model, &par, platform, predictor);
        let p = proportions(&cp, &model, &par);
        rows.push(vec![
            p.label.clone(),
            format!("{:.1}%", p.stage_fwd),
            format!("{:.1}%", p.stage_bwd),
            format!("{:.1}%", p.dp_allreduce),
            format!("{:.1}%", p.update),
            format!("{:.1}%", p.encoder_fwd),
            format!("{:.1}%", p.encoder_bwd),
            format!("{:.1}%", p.mp_allreduce),
            format!("{:.1}%", p.pp_p2p),
        ]);
    }
    let headers: Vec<String> = [
        "Config",
        "Stage_Fwd",
        "Stage_Bwd",
        "DP_Allreduce",
        "Update",
        "Encoder_Fwd",
        "Encoder_Bwd",
        "MP_Allreduce",
        "PP_P2P",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    format!(
        "# Figure 3 — Component time-cost proportions on {} (estimated)\n\n\
         Proportions sum past 100%: only Stage_Fwd/Stage_Bwd/DP_Allreduce/Update are\n\
         mutually exclusive phases (see paper §IV-C).\n\n{}",
        platform.name,
        crate::report::tables::markdown_table(&headers, &rows)
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::e2e::OraclePredictor;

    #[test]
    fn fig2_renders_all_schedules_and_measured_shape() {
        let md = fig2_markdown(
            &ModelCfg::llemma7b(),
            &ParallelCfg::new(4, 2, 2),
            &Platform::perlmutter(),
        );
        assert!(md.contains("Stage1"));
        assert!(md.contains("Stage4"));
        // four canonical schedule renders + one measured-shape render
        assert!(md.matches("```").count() >= 10);
        assert!(md.contains("`1f1b`"));
        assert!(md.contains("`gpipe`"));
        assert!(md.contains("`interleaved:2`"));
        assert!(md.contains("`zb-h1`"));
    }

    #[test]
    fn fig2_measured_shape_follows_cfg_schedule() {
        use crate::pipeline::ScheduleKind;
        let md = fig2_markdown(
            &ModelCfg::llemma7b(),
            &ParallelCfg::new(4, 2, 2).with_schedule(ScheduleKind::GPipe),
            &Platform::perlmutter(),
        );
        assert!(md.contains("(4-2-2/gpipe)"), "{md}");
    }

    #[test]
    fn proportions_sane() {
        let p = Platform::perlmutter();
        let model = ModelCfg::gpt20b();
        let par = ParallelCfg::new(4, 4, 8);
        let mut oracle = OraclePredictor { platform: p.clone() };
        let cp = predict(&model, &par, &p, &mut oracle);
        let pr = proportions(&cp, &model, &par);
        // pipeline phases dominate: fwd+bwd should be 70-100% of runtime
        let main = pr.stage_fwd + pr.stage_bwd;
        assert!((60.0..105.0).contains(&main), "stage share {main}");
        // comms are small on Perlmutter mp=4 (intra-node)
        assert!(pr.dp_allreduce < 20.0);
        assert!(pr.pp_p2p < 10.0);
        // encoder share sits within the stage share
        assert!(pr.encoder_fwd <= pr.stage_fwd + 5.0);
    }
}
