//! Black-box end-to-end baseline: fit batch time directly to coarse
//! configuration descriptors (GPU count, hidden dim, sequence, encoders,
//! micro-batches) from a handful of full training runs — "fitting
//! iteration time to GPU count or hidden dimension size", the approach
//! the paper's §II-B calls insufficient. Needs expensive end-to-end runs
//! as training data AND misses parallelism-layout effects entirely
//! (4-8-4 vs 8-4-4 look identical to it at equal GPU counts).

use crate::config::{ModelCfg, ParallelCfg, Platform};
use crate::trainrun::stability;

/// Log-linear scaling-law fit over end-to-end runs.
pub struct BlackBox {
    /// weights for [ln gpus, ln d, ln l, ln encoders, ln micro, 1]
    w: Vec<f64>,
}

fn features(model: &ModelCfg, par: &ParallelCfg) -> Vec<f64> {
    vec![
        (par.gpus() as f64).ln(),
        (model.d as f64).ln(),
        (model.l as f64).ln(),
        (model.encoders as f64).ln(),
        (model.iters_per_update as f64).ln(),
        1.0,
    ]
}

impl BlackBox {
    /// Train from measured (config -> seconds) pairs. In the ablation
    /// bench these come from actual simulated runs — the expensive data
    /// the paper's method avoids needing.
    pub fn train(runs: &[(ModelCfg, ParallelCfg, f64)]) -> BlackBox {
        let x: Vec<Vec<f64>> = runs.iter().map(|(m, p, _)| features(m, p)).collect();
        let y: Vec<f64> = runs.iter().map(|(_, _, s)| s.ln()).collect();
        // least squares via normal equations (reuse the ridge in linear.rs
        // is private; tiny local copy with lambda smoothing)
        let d = x[0].len();
        let mut ata = vec![vec![0.0; d]; d];
        let mut aty = vec![0.0; d];
        for (row, &yi) in x.iter().zip(&y) {
            for i in 0..d {
                aty[i] += row[i] * yi;
                for j in 0..d {
                    ata[i][j] += row[i] * row[j];
                }
            }
        }
        for (i, row) in ata.iter_mut().enumerate() {
            row[i] += 1e-6;
        }
        let mut m = ata;
        let mut b = aty;
        for col in 0..d {
            let piv = (col..d)
                .max_by(|&a, &bb| m[a][col].abs().total_cmp(&m[bb][col].abs()))
                .unwrap();
            m.swap(col, piv);
            b.swap(col, piv);
            let diag = m[col][col];
            for r in 0..d {
                if r == col {
                    continue;
                }
                let f = m[r][col] / diag;
                for c in col..d {
                    m[r][c] -= f * m[col][c];
                }
                b[r] -= f * b[col];
            }
        }
        let w = (0..d).map(|i| b[i] / m[i][i]).collect();
        BlackBox { w }
    }

    /// Convenience: train from simulated runs on a set of configs.
    pub fn train_from_sim(
        configs: &[(ModelCfg, ParallelCfg)],
        platform: &Platform,
        seed: u64,
    ) -> BlackBox {
        let runs: Vec<(ModelCfg, ParallelCfg, f64)> = configs
            .iter()
            .map(|(m, p)| {
                let st = stability(m, p, platform, 2, seed);
                (m.clone(), *p, st.min_s)
            })
            .collect();
        BlackBox::train(&runs)
    }

    /// Predicted batch seconds.
    pub fn predict_s(&self, model: &ModelCfg, par: &ParallelCfg) -> f64 {
        let f = features(model, par);
        let log: f64 = self.w.iter().zip(&f).map(|(a, b)| a * b).sum();
        log.exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolates_training_points() {
        let p = Platform::perlmutter();
        let configs = vec![
            (ModelCfg::llemma7b(), ParallelCfg::new(2, 2, 2)),
            (ModelCfg::llemma7b(), ParallelCfg::new(4, 2, 2)),
            (ModelCfg::llama13b(), ParallelCfg::new(4, 4, 2)),
            (ModelCfg::gpt20b(), ParallelCfg::new(4, 4, 4)),
            (ModelCfg::gpt20b(), ParallelCfg::new(4, 4, 8)),
        ];
        let bb = BlackBox::train_from_sim(&configs, &p, 7);
        // in-sample predictions within 2x (it is a crude model)
        for (m, par) in &configs {
            let st = stability(m, par, &p, 2, 7);
            let pred = bb.predict_s(m, par);
            let ratio = pred / st.min_s;
            assert!((0.4..2.5).contains(&ratio), "{} {}: ratio {ratio}", m.name, par);
        }
    }

    #[test]
    fn blind_to_parallelism_layout() {
        // The defining failure: 4-8-4 and 8-4-4 (same GPU count) get the
        // SAME prediction even though measured times differ substantially.
        let p = Platform::perlmutter();
        let configs = vec![
            (ModelCfg::gpt20b(), ParallelCfg::new(4, 4, 4)),
            (ModelCfg::llama13b(), ParallelCfg::new(4, 4, 2)),
            (ModelCfg::llemma7b(), ParallelCfg::new(2, 2, 2)),
        ];
        let bb = BlackBox::train_from_sim(&configs, &p, 3);
        let m = ModelCfg::gpt20b();
        let a = bb.predict_s(&m, &ParallelCfg::new(4, 8, 4));
        let b = bb.predict_s(&m, &ParallelCfg::new(8, 4, 4));
        assert!((a - b).abs() < 1e-9);
    }
}
