//! Comparator models the paper argues against (§I, §V): a purely
//! analytical roofline predictor, a log-linear regression, and a
//! black-box end-to-end scaling-law fit. All implement [`BatchPredictor`]
//! (or the e2e equivalent) so the ablation benches swap them in directly.

pub mod analytical;
pub mod linear;
pub mod blackbox;

pub use analytical::Analytical;
pub use blackbox::BlackBox;
pub use linear::LogLinear;
