//! Log-log linear regression baseline: per-operator ridge fit of
//! log1p(latency) against log1p(features) — the "simple learned model"
//! middle ground between the analytical roofline and tree ensembles.
//! Smooth by construction, so it cannot represent the step
//! discontinuities that motivate the paper's tree-based choice.

use std::collections::HashMap;

use crate::predictor::registry::BatchPredictor;
use crate::sampling::{Dataset, DatasetKey};

/// One fitted model per operator key.
pub struct LogLinear {
    pub models: HashMap<DatasetKey, Vec<f64>>, // weights, bias last
}

fn phi(row: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> = row.iter().map(|&x| x.max(0.0).ln_1p()).collect();
    v.push(1.0); // bias
    v
}

/// Solve (A^T A + λI) w = A^T y by Gaussian elimination with partial
/// pivoting (dims are tiny: <= 9).
fn ridge(x: &[Vec<f64>], y: &[f64], lambda: f64) -> Vec<f64> {
    let d = x[0].len();
    let mut ata = vec![vec![0.0; d]; d];
    let mut aty = vec![0.0; d];
    for (row, &yi) in x.iter().zip(y) {
        for i in 0..d {
            aty[i] += row[i] * yi;
            for j in 0..d {
                ata[i][j] += row[i] * row[j];
            }
        }
    }
    for (i, row) in ata.iter_mut().enumerate() {
        row[i] += lambda;
    }
    // gaussian elimination
    let mut m = ata;
    let mut b = aty;
    for col in 0..d {
        let piv = (col..d)
            .max_by(|&a, &bb| m[a][col].abs().total_cmp(&m[bb][col].abs()))
            .unwrap();
        m.swap(col, piv);
        b.swap(col, piv);
        let diag = m[col][col];
        assert!(diag.abs() > 1e-12, "singular system");
        for r in 0..d {
            if r == col {
                continue;
            }
            let f = m[r][col] / diag;
            for c in col..d {
                m[r][c] -= f * m[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    (0..d).map(|i| b[i] / m[i][i]).collect()
}

impl LogLinear {
    pub fn train(datasets: &HashMap<DatasetKey, Dataset>) -> LogLinear {
        let mut models = HashMap::new();
        for (key, ds) in datasets {
            let x: Vec<Vec<f64>> = ds.x.iter().map(|r| phi(r)).collect();
            let y: Vec<f64> = ds.y.iter().map(|v| v.ln_1p()).collect();
            models.insert(*key, ridge(&x, &y, 1e-6));
        }
        LogLinear { models }
    }

    pub fn predict_row(&self, key: DatasetKey, row: &[f64]) -> f64 {
        let w = self.models.get(&key).unwrap_or_else(|| panic!("no model for {key:?}"));
        let f = phi(row);
        let log_pred: f64 = w.iter().zip(&f).map(|(a, b)| a * b).sum();
        log_pred.exp_m1().max(0.0)
    }
}

impl BatchPredictor for LogLinear {
    fn predict_batch(&mut self, key: DatasetKey, rows: &[Vec<f64>]) -> Vec<f64> {
        rows.iter().map(|r| self.predict_row(key, r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{Dir, OpKind};
    use crate::util::rng::Rng;
    use crate::util::stats;

    fn key() -> DatasetKey {
        (OpKind::Linear1, Dir::Fwd)
    }

    fn power_law_dataset(seed: u64, n: usize) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut ds = Dataset::default();
        for _ in 0..n {
            let a = rng.uniform(100.0, 10_000.0);
            let b = rng.uniform(1.0, 16.0);
            ds.push(vec![a, b], 2.0 * a.powf(0.9) / b.powf(0.5));
        }
        ds
    }

    #[test]
    fn fits_power_laws_well() {
        let mut data = HashMap::new();
        data.insert(key(), power_law_dataset(1, 400));
        let mut m = LogLinear::train(&data);
        let ds = &data[&key()];
        let pred = m.predict_batch(key(), &ds.x);
        let mape = stats::mape(&pred, &ds.y);
        assert!(mape < 8.0, "MAPE {mape}");
    }

    #[test]
    fn cannot_fit_steps() {
        // A hard step is exactly what log-linear smooths over.
        let mut rng = Rng::new(2);
        let mut ds = Dataset::default();
        for _ in 0..400 {
            let a = rng.uniform(1.0, 100.0);
            ds.push(vec![a], if a <= 50.0 { 10.0 } else { 100.0 });
        }
        let mut data = HashMap::new();
        data.insert(key(), ds);
        let mut m = LogLinear::train(&data);
        let ds = &data[&key()];
        let pred = m.predict_batch(key(), &ds.x);
        let mape = stats::mape(&pred, &ds.y);
        assert!(mape > 15.0, "a linear model should NOT fit steps: {mape}");
    }

    #[test]
    fn ridge_solves_exact_system() {
        // y = 3*x0 + 2*x1 + 1 (in phi space directly)
        let x = vec![
            vec![1.0, 0.0, 1.0],
            vec![0.0, 1.0, 1.0],
            vec![1.0, 1.0, 1.0],
            vec![2.0, 1.0, 1.0],
        ];
        let y = vec![4.0, 3.0, 6.0, 9.0];
        let w = ridge(&x, &y, 1e-9);
        assert!((w[0] - 3.0).abs() < 1e-4);
        assert!((w[1] - 2.0).abs() < 1e-4);
        assert!((w[2] - 1.0).abs() < 1e-4);
    }
}
