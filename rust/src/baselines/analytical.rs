//! Purely analytical roofline baseline: FLOPs / peak + bytes / bandwidth
//! + α-β collectives, computed from the same Table-I feature vectors the
//! regressors see — but with NO knowledge of kernel selection, tile/wave
//! quantization, cache regimes, protocol switches, or hierarchy.
//!
//! This is the "conventional, overly-simplistic analytical approach" of
//! the paper's introduction; the ablation bench quantifies how much the
//! sampled regressors buy over it.

use crate::config::{ModelCfg, ParallelCfg, Platform};
use crate::net::{CommGeom, INTER_MAX_EFF};
use crate::ops::build::{dp_allgather, dp_allreduce, encoder_ops, optimizer, Workload};
use crate::ops::params::{stage_params_paper, StageRole};
use crate::ops::{Dir, LoweredOp, OpKind};
use crate::pipeline::encoder_allocation;
use crate::predictor::registry::BatchPredictor;
use crate::sampling::DatasetKey;

pub struct Analytical {
    pub platform: Platform,
    /// Assumed fraction of peak for compute ops (a flat, optimistic 80%).
    pub flat_efficiency: f64,
}

impl Analytical {
    pub fn new(platform: Platform) -> Analytical {
        Analytical { platform, flat_efficiency: 0.8 }
    }

    fn gemm_us(&self, flops: f64, bytes: f64) -> f64 {
        let g = &self.platform.gpu;
        let t_c = flops / (g.peak_tflops_fp16 * 1e12 * self.flat_efficiency) * 1e6;
        let t_m = bytes / (g.mem_bw_gbs * 1e9) * 1e6;
        t_c.max(t_m)
    }

    fn mem_us(&self, bytes: f64) -> f64 {
        // flat HBM bandwidth, two passes, no cache model, no launch cost
        2.0 * bytes / (self.platform.gpu.mem_bw_gbs * 1e9) * 1e6
    }

    /// α-β ring all-reduce with no hierarchy: every member is assumed to
    /// sit behind the slowest link in the group.
    fn allreduce_us(&self, bytes: f64, nodes: f64, gpn: f64) -> f64 {
        let p = (nodes * gpn).max(1.0);
        if p <= 1.0 {
            return 0.0;
        }
        let bw = if nodes > 1.0 { self.platform.inter_bw_gbs } else { self.platform.intra_bw_gbs };
        let lat = if nodes > 1.0 { self.platform.inter_lat_us } else { self.platform.intra_lat_us };
        2.0 * (p - 1.0) / p * bytes / (bw * 1e9) * 1e6 + 2.0 * (p - 1.0) * lat
    }

    /// Predict from a Table-I feature row (the same inputs the forests
    /// get) by reconstructing the op's FLOPs/bytes analytically.
    pub fn predict_row(&self, key: DatasetKey, f: &[f64]) -> f64 {
        let (kind, dir) = key;
        let bwd_factor = match dir {
            Dir::Fwd => 1.0,
            Dir::Bwd => 2.0, // dgrad + wgrad, the textbook assumption
        };
        let t = match kind {
            OpKind::Linear1 | OpKind::Linear2 | OpKind::Linear3 | OpKind::Linear4
            | OpKind::FinalLinear => {
                // [m, k, n]
                let (m, k, n) = (f[0], f[1], f[2]);
                self.gemm_us(2.0 * m * k * n, 2.0 * (m * k + k * n + m * n))
            }
            OpKind::QkT => {
                // [batch, l, dh, l]
                let (b, l, dh, l2) = (f[0], f[1], f[2], f[3]);
                self.gemm_us(2.0 * b * l * dh * l2, 2.0 * b * (l * dh + dh * l2 + l * l2))
            }
            OpKind::AttnV => {
                let (b, l, l2, dh) = (f[0], f[1], f[2], f[3]);
                self.gemm_us(2.0 * b * l * l2 * dh, 2.0 * b * (l * l2 + l2 * dh + l * dh))
            }
            OpKind::FlashAttention => {
                let (b, l, hl, dh) = (f[0], f[1], f[2], f[3]);
                self.gemm_us(4.0 * b * l * l * hl * dh, 8.0 * b * l * hl * dh)
            }
            OpKind::Embedding => self.mem_us(f[0] * f[2] * 2.0),
            OpKind::LayerNorm | OpKind::RmsNorm => self.mem_us(f[0] * f[1] * f[2] * 2.0),
            OpKind::Rope => self.mem_us(f[0] * f[1] * f[2] * f[3] * 2.0),
            OpKind::Fillmask => self.mem_us(f[0] * f[1] * f[2] * f[2] * 2.0),
            OpKind::Softmax => self.mem_us(f[0] * f[1] * f[2] * f[3] * 2.0),
            OpKind::FusedSoftmax => self.mem_us(f[0] * f[1] * f[2] * 2.0),
            OpKind::Glue => self.mem_us(f[0] * f[1] * f[2] * 2.0),
            OpKind::ParallelCrossEntropy => self.mem_us(f[0] * f[1] * f[2] * 2.0),
            OpKind::MpAllReduce | OpKind::DpAllReduce => {
                self.allreduce_us(f[0] * 2.0, f[1], f[2])
            }
            OpKind::DpAllGather => 0.5 * self.allreduce_us(f[0] * 2.0, f[1], f[2]),
            OpKind::PpP2p => {
                let bytes = f[0] * 2.0;
                let inter = f[1] > 1.0;
                let bw = if inter { self.platform.inter_bw_gbs } else { self.platform.intra_bw_gbs };
                bytes / (bw * 1e9) * 1e6
            }
            OpKind::Optimizer => {
                // [mp, dim, encoders]: Adam state traffic at flat HBM bw
                self.mem_us(f[1] * 8.0)
            }
        };
        t * bwd_factor
    }
}

impl BatchPredictor for Analytical {
    fn predict_batch(&mut self, key: DatasetKey, rows: &[Vec<f64>]) -> Vec<f64> {
        rows.iter().map(|r| self.predict_row(key, r)).collect()
    }
}

// ---------------------------------------------------------------------------
// Admissible lower bounds (branch-and-bound pruning support)
//
// Unlike [`Analytical::predict_row`] above — a deliberately sloppy flat-80%
// comparator that OVERestimates many ops — these floors are provable
// UNDERestimates of `sim::deterministic_us` for every lowered op: compute at
// full peak (the simulator's efficiency model never exceeds 1), memory
// traffic at L2 bandwidth (the logistic blend is bounded above by it),
// collective volume on the fastest tier at the maximum efficiency the
// collective model can reach, and no launch/latency/reduction/contention
// terms anywhere. `sweep::Engine` uses them to skip configs that provably
// cannot reach the running top-k.
// ---------------------------------------------------------------------------

/// Ring all-reduce volume floor: `2(P-1)/P · bytes` on the fastest tier at
/// unit efficiency, refined for node-spanning groups by the inter-node
/// stage's own floor (the hierarchical model must move at least the
/// per-leader shard across the fabric at ≤ [`INTER_MAX_EFF`]).
fn allreduce_floor_us(bytes: f64, geom: CommGeom, platform: &Platform) -> f64 {
    if geom.world() <= 1 {
        return 0.0;
    }
    let p = geom.world() as f64;
    let bw_best = platform.intra_bw_gbs.max(platform.inter_bw_gbs);
    let mut floor = 2.0 * (p - 1.0) / p * bytes / (bw_best * 1e9) * 1e6;
    if geom.nodes > 1 {
        let n = geom.nodes as f64;
        let shard = bytes / geom.gpus_per_node as f64;
        let spanning =
            2.0 * (n - 1.0) / n * shard / (platform.inter_bw_gbs * INTER_MAX_EFF * 1e9) * 1e6;
        floor = floor.max(spanning);
    }
    floor
}

/// All-gather analog: one-directional `(P-1)/P · bytes_out` volume.
fn allgather_floor_us(bytes_out: f64, geom: CommGeom, platform: &Platform) -> f64 {
    if geom.world() <= 1 {
        return 0.0;
    }
    let p = geom.world() as f64;
    let volume = (p - 1.0) / p * bytes_out;
    let bw_best = platform.intra_bw_gbs.max(platform.inter_bw_gbs);
    let mut floor = volume / (bw_best * 1e9) * 1e6;
    if geom.nodes > 1 {
        let spanning = volume / (platform.inter_bw_gbs * INTER_MAX_EFF * 1e9) * 1e6;
        floor = floor.max(spanning);
    }
    floor
}

/// Admissible per-op floor, µs: provably ≤ `sim::deterministic_us(op)` on
/// the same platform, for every op variant and every topology (rail/spine
/// fabrics only ever LOWER the effective inter-node bandwidth relative to
/// the flat `inter_bw_gbs` these floors assume).
pub fn op_floor_us(op: &LoweredOp, platform: &Platform) -> f64 {
    let gpu = &platform.gpu;
    match op {
        // eff = base_eff·util_tile·util_wave·(0.55+0.45·k_eff) ≤ 0.62 < 1,
        // and the HBM floor + launch only add time
        LoweredOp::Gemm(shape) => shape.flops() / (gpu.peak_tflops_fp16 * 1e12) * 1e6,
        // effective bandwidth is a logistic blend of l2_bw and mem_bw,
        // bounded above by l2_bw; reduction + launch terms dropped
        LoweredOp::Mem { kind, elems, elem_bytes, .. } => {
            elems * elem_bytes * kind.passes() / (gpu.l2_bw_gbs * 1e9) * 1e6
        }
        // the simulator divides peak by 0.60 — full peak is strictly below
        LoweredOp::Flash { flops, .. } => flops / (gpu.peak_tflops_fp16 * 1e12) * 1e6,
        LoweredOp::AllReduce { bytes, geom, .. } => allreduce_floor_us(*bytes, *geom, platform),
        LoweredOp::AllGather { bytes_out, geom, .. } => {
            allgather_floor_us(*bytes_out, *geom, platform)
        }
        // pure latency floor would be tier-dependent; 0 is trivially safe
        LoweredOp::P2p { .. } => 0.0,
        LoweredOp::Seq(ops) => ops.iter().map(|o| op_floor_us(o, platform)).sum(),
    }
}

/// Admissible lower bound on a config's predicted batch time, µs.
///
/// Every schedule's closed form is
/// `m·(max_fwd + max_bwd) + steady/bubble/P2P terms (all ≥ 0)
///  + first_stage_sync + max_update`, where `max_fwd`/`max_bwd` are maxima
/// over per-stage op-time sums. The heaviest stage holds
/// `max(encoder_allocation)` encoders, `first_stage_sync` is exactly stage
/// 0's DP all-reduce, and `max_update` is at least stage 0's
/// optimizer + all-gather — so summing per-op floors over one encoder
/// (forward + backward), scaling by `m · n_enc_max`, and adding stage 0's
/// sync/update floors can never exceed the engine's prediction under the
/// deterministic oracle. (Asserted over the gpt20b/128 enumeration in this
/// module's tests and over full sweeps in `tests/prop_sweep.rs`.)
pub fn sweep_lower_bound_us(model: &ModelCfg, par: &ParallelCfg, platform: &Platform) -> f64 {
    let wl = Workload::new(model, par, platform);
    let floor_sum = |dir: Dir| -> f64 {
        encoder_ops(model, &wl, dir).iter().map(|op| op_floor_us(&op.lowered, platform)).sum()
    };
    let enc_floor = floor_sum(Dir::Fwd) + floor_sum(Dir::Bwd);
    let alloc = encoder_allocation(model.encoders, par.pp);
    let n_enc_max = alloc.iter().copied().max().unwrap_or(0) as f64;
    let params0 =
        stage_params_paper(StageRole::of(0, par.pp), alloc[0], model.d, wl.v, par.mp);
    let sync_floor = op_floor_us(&dp_allreduce(params0, &wl).lowered, platform);
    let update_floor = op_floor_us(&optimizer(params0, alloc[0], &wl).lowered, platform)
        + op_floor_us(&dp_allgather(params0 / par.dp as f64, &wl).lowered, platform);
    let m = model.iters_per_update as f64;
    m * n_enc_max * enc_floor + sync_floor + update_floor
}

/// Compute-only floor on a config's batch time, µs: the heaviest stage's
/// encoder GEMM/memory/flash floors (collectives and P2P excluded) over
/// all `m` micro-batches. This is the irreducible ideal-FLOP time of a
/// step — `faults::GoodputParams::compute_frac` divides it by the
/// predicted step time to turn goodput into a useful-FLOP fraction.
/// A subset of [`sweep_lower_bound_us`]'s terms, so it inherits the same
/// admissibility argument (compute floors never exceed the simulator).
pub fn compute_floor_us(model: &ModelCfg, par: &ParallelCfg, platform: &Platform) -> f64 {
    fn is_compute(op: &LoweredOp) -> bool {
        match op {
            LoweredOp::Gemm(_) | LoweredOp::Mem { .. } | LoweredOp::Flash { .. } => true,
            LoweredOp::Seq(v) => v.iter().all(is_compute),
            _ => false,
        }
    }
    let wl = Workload::new(model, par, platform);
    let compute_sum = |dir: Dir| -> f64 {
        encoder_ops(model, &wl, dir)
            .iter()
            .filter(|op| is_compute(&op.lowered))
            .map(|op| op_floor_us(&op.lowered, platform))
            .sum()
    };
    let enc_floor = compute_sum(Dir::Fwd) + compute_sum(Dir::Bwd);
    let alloc = encoder_allocation(model.encoders, par.pp);
    let n_enc_max = alloc.iter().copied().max().unwrap_or(0) as f64;
    model.iters_per_update as f64 * n_enc_max * enc_floor
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelCfg, ParallelCfg};
    use crate::ops::build::{compute_op, Workload};
    use crate::sim::deterministic_us;

    fn setup() -> (Analytical, Workload, Platform) {
        let p = Platform::perlmutter();
        let wl = Workload::new(
            &ModelCfg::gpt20b(),
            &ParallelCfg::new(4, 4, 8),
            &p,
        );
        (Analytical::new(p.clone()), wl, p)
    }

    #[test]
    fn right_order_of_magnitude_for_gemms() {
        let (mut a, wl, p) = setup();
        let op = compute_op(OpKind::Linear1, &wl, Dir::Fwd);
        let pred = a.predict_batch((op.kind, op.dir), &[op.features.clone()])[0];
        let actual = deterministic_us(&op.lowered, &p);
        let ratio = pred / actual;
        assert!((0.3..3.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn systematically_optimistic_on_small_gemms() {
        // Flat 80% efficiency ignores wave quantization: small GEMMs are
        // badly underestimated — the failure mode that motivates sampling.
        let (mut a, _, p) = setup();
        let wl_small = Workload::synthetic(4, 1024, 2048, 16, 50257, 16, &p, 2);
        let op = compute_op(OpKind::Linear2, &wl_small, Dir::Fwd);
        let pred = a.predict_batch((op.kind, op.dir), &[op.features.clone()])[0];
        let actual = deterministic_us(&op.lowered, &p);
        assert!(pred < actual, "pred {pred} actual {actual}");
    }

    #[test]
    fn ignores_hierarchy_for_collectives() {
        // Analytical sees (8 nodes x 1 gpu) and (2 nodes x 4 gpus) as the
        // same world size behind the inter-node link; the simulator's
        // hierarchical model makes the packed layout much faster.
        let (mut a, _, _) = setup();
        let bytes_entries = 1e8;
        let spread = a.predict_batch(
            (OpKind::DpAllReduce, Dir::Fwd),
            &[vec![bytes_entries, 8.0, 1.0]],
        )[0];
        let packed = a.predict_batch(
            (OpKind::DpAllReduce, Dir::Fwd),
            &[vec![bytes_entries, 2.0, 4.0]],
        )[0];
        // same volume term; analytical barely distinguishes them
        let rel = (spread - packed).abs() / spread;
        assert!(rel < 0.3, "{spread} vs {packed}");
    }

    #[test]
    fn op_floor_below_deterministic_for_every_lowered_op() {
        // Per-op admissibility across models, parallelisms, and both
        // directions: the floor must never exceed the simulator's
        // deterministic time for any op the planner can build.
        use crate::ops::build::{encoder_ops, post_encoder_ops, pre_encoder_ops};
        for model in ModelCfg::all() {
            for par in [ParallelCfg::new(4, 4, 8), ParallelCfg::new(2, 8, 8), ParallelCfg::new(1, 1, 16)] {
                for p in [Platform::perlmutter(), Platform::vista()] {
                    let wl = Workload::new(&model, &par, &p);
                    for dir in [Dir::Fwd, Dir::Bwd] {
                        let mut ops = encoder_ops(&model, &wl, dir);
                        ops.extend(pre_encoder_ops(&model, &wl, dir));
                        ops.extend(post_encoder_ops(&model, &wl, dir));
                        for op in &ops {
                            let floor = op_floor_us(&op.lowered, &p);
                            let det = deterministic_us(&op.lowered, &p);
                            assert!(
                                floor <= det,
                                "{} {:?} {:?} on {}: floor {floor} > det {det}",
                                model.name, op.kind, dir, p.name
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn bound_admissible_over_gpt20b_128_enumeration() {
        // The branch-and-bound contract: for EVERY feasible config in the
        // gpt20b/128 smoke enumeration (all schedules x all rank maps),
        // the analytical lower bound must sit at or below the full engine
        // prediction — otherwise pruning could drop a true top-k row.
        use crate::net::topology::RankOrder;
        use crate::pipeline::ScheduleKind;
        use crate::predictor::e2e::OraclePredictor;
        use crate::sweep::{Engine, SweepSpec};

        let model = ModelCfg::gpt20b();
        let platform = Platform::perlmutter();
        let mut spec = SweepSpec::new(128);
        spec.schedules = ScheduleKind::all(2);
        spec.rank_orders = RankOrder::all();
        let mut oracle = OraclePredictor { platform: platform.clone() };
        let report = Engine::new().sweep(&model, &platform, &spec, &mut oracle).unwrap();
        assert!(!report.rows.is_empty());
        for row in &report.rows {
            let bound = sweep_lower_bound_us(&model, &row.par, &platform);
            assert!(
                bound <= row.prediction.total_us,
                "inadmissible bound for {}: {bound} > {}",
                row.par.label(),
                row.prediction.total_us
            );
            assert!(bound > 0.0, "degenerate bound for {}", row.par.label());
        }
    }

    #[test]
    fn compute_floor_positive_and_below_full_bound() {
        // The compute-only floor is a strict subset of the full bound's
        // terms, so it must sit in (0, sweep_lower_bound_us].
        for model in ModelCfg::all() {
            for par in [ParallelCfg::new(4, 4, 8), ParallelCfg::new(1, 4, 4)] {
                let p = Platform::perlmutter();
                let cf = compute_floor_us(&model, &par, &p);
                let full = sweep_lower_bound_us(&model, &par, &p);
                assert!(cf > 0.0, "{} {}", model.name, par.label());
                assert!(cf <= full, "{} {}: {cf} > {full}", model.name, par.label());
            }
        }
    }

    #[test]
    fn covers_all_op_kinds() {
        let (mut a, wl, _) = setup();
        for kind in OpKind::ALL {
            let features = if kind.is_comm() {
                vec![1e7, 2.0, 4.0]
            } else if kind == OpKind::Optimizer {
                vec![4.0, 1e8, 11.0]
            } else {
                compute_op(kind, &wl, Dir::Fwd).features
            };
            let v = a.predict_batch((kind, Dir::Fwd), &[features])[0];
            assert!(v.is_finite() && v >= 0.0, "{kind:?} -> {v}");
        }
    }
}
