//! Purely analytical roofline baseline: FLOPs / peak + bytes / bandwidth
//! + α-β collectives, computed from the same Table-I feature vectors the
//! regressors see — but with NO knowledge of kernel selection, tile/wave
//! quantization, cache regimes, protocol switches, or hierarchy.
//!
//! This is the "conventional, overly-simplistic analytical approach" of
//! the paper's introduction; the ablation bench quantifies how much the
//! sampled regressors buy over it.

use crate::config::Platform;
use crate::ops::{Dir, OpKind};
use crate::predictor::registry::BatchPredictor;
use crate::sampling::DatasetKey;

pub struct Analytical {
    pub platform: Platform,
    /// Assumed fraction of peak for compute ops (a flat, optimistic 80%).
    pub flat_efficiency: f64,
}

impl Analytical {
    pub fn new(platform: Platform) -> Analytical {
        Analytical { platform, flat_efficiency: 0.8 }
    }

    fn gemm_us(&self, flops: f64, bytes: f64) -> f64 {
        let g = &self.platform.gpu;
        let t_c = flops / (g.peak_tflops_fp16 * 1e12 * self.flat_efficiency) * 1e6;
        let t_m = bytes / (g.mem_bw_gbs * 1e9) * 1e6;
        t_c.max(t_m)
    }

    fn mem_us(&self, bytes: f64) -> f64 {
        // flat HBM bandwidth, two passes, no cache model, no launch cost
        2.0 * bytes / (self.platform.gpu.mem_bw_gbs * 1e9) * 1e6
    }

    /// α-β ring all-reduce with no hierarchy: every member is assumed to
    /// sit behind the slowest link in the group.
    fn allreduce_us(&self, bytes: f64, nodes: f64, gpn: f64) -> f64 {
        let p = (nodes * gpn).max(1.0);
        if p <= 1.0 {
            return 0.0;
        }
        let bw = if nodes > 1.0 { self.platform.inter_bw_gbs } else { self.platform.intra_bw_gbs };
        let lat = if nodes > 1.0 { self.platform.inter_lat_us } else { self.platform.intra_lat_us };
        2.0 * (p - 1.0) / p * bytes / (bw * 1e9) * 1e6 + 2.0 * (p - 1.0) * lat
    }

    /// Predict from a Table-I feature row (the same inputs the forests
    /// get) by reconstructing the op's FLOPs/bytes analytically.
    pub fn predict_row(&self, key: DatasetKey, f: &[f64]) -> f64 {
        let (kind, dir) = key;
        let bwd_factor = match dir {
            Dir::Fwd => 1.0,
            Dir::Bwd => 2.0, // dgrad + wgrad, the textbook assumption
        };
        let t = match kind {
            OpKind::Linear1 | OpKind::Linear2 | OpKind::Linear3 | OpKind::Linear4
            | OpKind::FinalLinear => {
                // [m, k, n]
                let (m, k, n) = (f[0], f[1], f[2]);
                self.gemm_us(2.0 * m * k * n, 2.0 * (m * k + k * n + m * n))
            }
            OpKind::QkT => {
                // [batch, l, dh, l]
                let (b, l, dh, l2) = (f[0], f[1], f[2], f[3]);
                self.gemm_us(2.0 * b * l * dh * l2, 2.0 * b * (l * dh + dh * l2 + l * l2))
            }
            OpKind::AttnV => {
                let (b, l, l2, dh) = (f[0], f[1], f[2], f[3]);
                self.gemm_us(2.0 * b * l * l2 * dh, 2.0 * b * (l * l2 + l2 * dh + l * dh))
            }
            OpKind::FlashAttention => {
                let (b, l, hl, dh) = (f[0], f[1], f[2], f[3]);
                self.gemm_us(4.0 * b * l * l * hl * dh, 8.0 * b * l * hl * dh)
            }
            OpKind::Embedding => self.mem_us(f[0] * f[2] * 2.0),
            OpKind::LayerNorm | OpKind::RmsNorm => self.mem_us(f[0] * f[1] * f[2] * 2.0),
            OpKind::Rope => self.mem_us(f[0] * f[1] * f[2] * f[3] * 2.0),
            OpKind::Fillmask => self.mem_us(f[0] * f[1] * f[2] * f[2] * 2.0),
            OpKind::Softmax => self.mem_us(f[0] * f[1] * f[2] * f[3] * 2.0),
            OpKind::FusedSoftmax => self.mem_us(f[0] * f[1] * f[2] * 2.0),
            OpKind::Glue => self.mem_us(f[0] * f[1] * f[2] * 2.0),
            OpKind::ParallelCrossEntropy => self.mem_us(f[0] * f[1] * f[2] * 2.0),
            OpKind::MpAllReduce | OpKind::DpAllReduce => {
                self.allreduce_us(f[0] * 2.0, f[1], f[2])
            }
            OpKind::DpAllGather => 0.5 * self.allreduce_us(f[0] * 2.0, f[1], f[2]),
            OpKind::PpP2p => {
                let bytes = f[0] * 2.0;
                let inter = f[1] > 1.0;
                let bw = if inter { self.platform.inter_bw_gbs } else { self.platform.intra_bw_gbs };
                bytes / (bw * 1e9) * 1e6
            }
            OpKind::Optimizer => {
                // [mp, dim, encoders]: Adam state traffic at flat HBM bw
                self.mem_us(f[1] * 8.0)
            }
        };
        t * bwd_factor
    }
}

impl BatchPredictor for Analytical {
    fn predict_batch(&mut self, key: DatasetKey, rows: &[Vec<f64>]) -> Vec<f64> {
        rows.iter().map(|r| self.predict_row(key, r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelCfg, ParallelCfg};
    use crate::ops::build::{compute_op, Workload};
    use crate::sim::deterministic_us;

    fn setup() -> (Analytical, Workload, Platform) {
        let p = Platform::perlmutter();
        let wl = Workload::new(
            &ModelCfg::gpt20b(),
            &ParallelCfg::new(4, 4, 8),
            &p,
        );
        (Analytical::new(p.clone()), wl, p)
    }

    #[test]
    fn right_order_of_magnitude_for_gemms() {
        let (mut a, wl, p) = setup();
        let op = compute_op(OpKind::Linear1, &wl, Dir::Fwd);
        let pred = a.predict_batch((op.kind, op.dir), &[op.features.clone()])[0];
        let actual = deterministic_us(&op.lowered, &p);
        let ratio = pred / actual;
        assert!((0.3..3.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn systematically_optimistic_on_small_gemms() {
        // Flat 80% efficiency ignores wave quantization: small GEMMs are
        // badly underestimated — the failure mode that motivates sampling.
        let (mut a, _, p) = setup();
        let wl_small = Workload::synthetic(4, 1024, 2048, 16, 50257, 16, &p, 2);
        let op = compute_op(OpKind::Linear2, &wl_small, Dir::Fwd);
        let pred = a.predict_batch((op.kind, op.dir), &[op.features.clone()])[0];
        let actual = deterministic_us(&op.lowered, &p);
        assert!(pred < actual, "pred {pred} actual {actual}");
    }

    #[test]
    fn ignores_hierarchy_for_collectives() {
        // Analytical sees (8 nodes x 1 gpu) and (2 nodes x 4 gpus) as the
        // same world size behind the inter-node link; the simulator's
        // hierarchical model makes the packed layout much faster.
        let (mut a, _, _) = setup();
        let bytes_entries = 1e8;
        let spread = a.predict_batch(
            (OpKind::DpAllReduce, Dir::Fwd),
            &[vec![bytes_entries, 8.0, 1.0]],
        )[0];
        let packed = a.predict_batch(
            (OpKind::DpAllReduce, Dir::Fwd),
            &[vec![bytes_entries, 2.0, 4.0]],
        )[0];
        // same volume term; analytical barely distinguishes them
        let rel = (spread - packed).abs() / spread;
        assert!(rel < 0.3, "{spread} vs {packed}");
    }

    #[test]
    fn covers_all_op_kinds() {
        let (mut a, wl, _) = setup();
        for kind in OpKind::ALL {
            let features = if kind.is_comm() {
                vec![1e7, 2.0, 4.0]
            } else if kind == OpKind::Optimizer {
                vec![4.0, 1e8, 11.0]
            } else {
                compute_op(kind, &wl, Dir::Fwd).features
            };
            let v = a.predict_batch((kind, Dir::Fwd), &[features])[0];
            assert!(v.is_finite() && v >= 0.0, "{kind:?} -> {v}");
        }
    }
}
