#!/usr/bin/env python3
"""CI perf-trajectory gate for the sweep bench.

Usage:
    python3 ci/bench_gate.py BENCH_sweep.json BENCH_baseline.json BENCH_trajectory.jsonl

Reads the record `cargo bench --bench bench_hotpath -- --smoke` wrote,
compares it against the committed baseline, appends it to the rolling
trajectory file (restored across runs via actions/cache, uploaded as an
artifact every run), and FAILS the job when:

  * `cache_hit_rate`    < HIT_RATE_FLOOR   (0.50) — the cross-config
    cache stopped deduplicating (absolute floor, baseline-independent);
  * `warm_hit_rate`     < WARM_RATE_FLOOR  (0.95) — the disk warm-start
    tier stopped serving a second cold process;
  * `configs_per_sec`   < (1 - TOLERANCE) x baseline — throughput
    regressed more than 30% vs the committed baseline. The tolerance is
    deliberately wide (shared CI runners are noisy) and the baseline is
    deliberately conservative; re-baseline BENCH_baseline.json when the
    bench fixture or runner class changes.
  * `pruned_frac`       < PRUNED_FRAC_FLOOR (0.30) — the branch-and-bound
    bound stopped skipping work on the all-schedules x all-rank-maps
    top-8 fixture (absolute floor, baseline-independent);
  * `batch_predict_ns_per_row` > (1 + TOLERANCE) x baseline — the flat
    SoA batched forest path regressed more than 30% per row.
  * `goodput_smoke_identical` != 1.0 — annotating a sweep with the
    fault-free FaultSpec no longer reproduces the plain sweep's rows
    bit-identically (the `--faults off` identity broke; absolute,
    baseline-independent).

The serve-plan smoke keys (`serveplan_configs_per_sec`,
`serveplan_cache_hit_rate`) are REQUIRED to be present (exit 2 when the
bench stops emitting them) but carry no threshold yet — they seed the
trajectory until a baseline exists.

Exit code 0 = gate passed, 1 = regression, 2 = malformed input.
"""

import json
import os
import sys
import time

HIT_RATE_FLOOR = 0.50
WARM_RATE_FLOOR = 0.95
PRUNED_FRAC_FLOOR = 0.30
TOLERANCE = 0.30


def die(code, msg):
    print(f"bench-gate: FAIL: {msg}", file=sys.stderr)
    sys.exit(code)


def main(argv):
    if len(argv) != 4:
        die(2, f"usage: {argv[0]} BENCH_sweep.json BENCH_baseline.json BENCH_trajectory.jsonl")
    actual_path, baseline_path, trajectory_path = argv[1], argv[2], argv[3]

    try:
        with open(actual_path) as f:
            actual = json.load(f)
        with open(baseline_path) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        die(2, f"cannot read inputs: {e}")

    for field in (
        "configs_evaluated",
        "configs_per_sec",
        "cache_hit_rate",
        "pruned_frac",
        "batch_predict_ns_per_row",
        "goodput_smoke_identical",
        # phase-attribution keys (presence only, no threshold: wall-clock
        # splits are informational until the trajectory shows a trend)
        "prefetch_us",
        "compose_us",
        "bound_us",
        # serve-plan smoke keys (presence only, no threshold: the serving
        # workload family must keep flowing through the shared op cache,
        # but its throughput has no baseline yet)
        "serveplan_configs_per_sec",
        "serveplan_cache_hit_rate",
    ):
        if field not in actual:
            die(2, f"{actual_path} missing '{field}': {actual}")
    if actual["configs_evaluated"] <= 0:
        die(2, f"no configs evaluated: {actual}")
    if not (0.0 <= actual["cache_hit_rate"] <= 1.0):
        die(2, f"cache_hit_rate out of [0,1]: {actual}")

    # append BEFORE gating so failed runs are visible in the history too
    record = {
        "ts": int(time.time()),
        "sha": os.environ.get("GITHUB_SHA", "local"),
        "run_id": os.environ.get("GITHUB_RUN_ID", "local"),
        "case": actual.get("case", "?"),
        "configs_evaluated": actual["configs_evaluated"],
        "configs_per_sec": actual["configs_per_sec"],
        "cache_hit_rate": actual["cache_hit_rate"],
        "warm_hit_rate": actual.get("warm_hit_rate"),
        "elapsed_us": actual.get("elapsed_us"),
        "pruned_frac": actual.get("pruned_frac"),
        "batch_predict_ns_per_row": actual.get("batch_predict_ns_per_row"),
        "batch_speedup": actual.get("batch_speedup"),
        "goodput_smoke_identical": actual.get("goodput_smoke_identical"),
        "prefetch_us": actual.get("prefetch_us"),
        "compose_us": actual.get("compose_us"),
        "bound_us": actual.get("bound_us"),
        "serveplan_configs_evaluated": actual.get("serveplan_configs_evaluated"),
        "serveplan_configs_per_sec": actual.get("serveplan_configs_per_sec"),
        "serveplan_cache_hit_rate": actual.get("serveplan_cache_hit_rate"),
    }
    with open(trajectory_path, "a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")
    with open(trajectory_path) as f:
        n = sum(1 for _ in f)
    print(f"bench-gate: appended run to {trajectory_path} ({n} records)")

    failures = []
    if actual["cache_hit_rate"] < HIT_RATE_FLOOR:
        failures.append(
            f"cache_hit_rate {actual['cache_hit_rate']:.3f} < floor {HIT_RATE_FLOOR}"
        )
    warm = actual.get("warm_hit_rate")
    if warm is not None and warm < WARM_RATE_FLOOR:
        failures.append(f"warm_hit_rate {warm:.3f} < floor {WARM_RATE_FLOOR}")
    base_cps = baseline.get("configs_per_sec", 0.0)
    floor_cps = (1.0 - TOLERANCE) * base_cps
    if actual["configs_per_sec"] < floor_cps:
        failures.append(
            f"configs_per_sec {actual['configs_per_sec']:.1f} < "
            f"{floor_cps:.1f} (= {1 - TOLERANCE:.0%} of baseline {base_cps:.1f})"
        )
    if actual["pruned_frac"] < PRUNED_FRAC_FLOOR:
        failures.append(
            f"pruned_frac {actual['pruned_frac']:.3f} < floor {PRUNED_FRAC_FLOOR}"
        )
    base_batch_ns = baseline.get("batch_predict_ns_per_row", 0.0)
    ceil_batch_ns = (1.0 + TOLERANCE) * base_batch_ns
    if base_batch_ns > 0.0 and actual["batch_predict_ns_per_row"] > ceil_batch_ns:
        failures.append(
            f"batch_predict_ns_per_row {actual['batch_predict_ns_per_row']:.0f} > "
            f"{ceil_batch_ns:.0f} (= {1 + TOLERANCE:.0%} of baseline {base_batch_ns:.0f})"
        )
    if actual["goodput_smoke_identical"] != 1.0:
        failures.append(
            f"goodput_smoke_identical {actual['goodput_smoke_identical']} != 1.0 "
            "(fault-free FaultSpec perturbed sweep rows)"
        )

    if failures:
        die(1, "; ".join(failures))
    print(
        f"bench-gate: PASS — {actual['configs_per_sec']:.1f} configs/s "
        f"(baseline {base_cps:.1f}), hit-rate {actual['cache_hit_rate']:.2f}, "
        f"warm {warm if warm is not None else 'n/a'}, "
        f"pruned {actual['pruned_frac']:.0%}, "
        f"batch {actual['batch_predict_ns_per_row']:.0f} ns/row"
    )


if __name__ == "__main__":
    main(sys.argv)
