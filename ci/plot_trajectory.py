#!/usr/bin/env python3
"""Render the rolling perf trajectory as a standalone SVG.

Usage:
    python3 ci/plot_trajectory.py BENCH_trajectory.jsonl BENCH_trajectory.svg

Reads the JSONL history ci/bench_gate.py appends to on every CI run and
draws two series over run index:

  * configs_per_sec (left axis, solid line) — sweep throughput;
  * cache_hit_rate  (right axis 0..1, dashed line) — cross-config
    op-cache effectiveness.

Stdlib only (no matplotlib on the runners); the output is uploaded as a
CI artifact next to the JSONL so a regression can be eyeballed without
downloading the history. Missing or empty input produces a placeholder
SVG and exit code 0 — the plot must never fail the job. Exit 2 only on
usage errors.
"""

import json
import sys

WIDTH, HEIGHT = 880, 360
MARGIN_L, MARGIN_R, MARGIN_T, MARGIN_B = 64, 64, 36, 44
PLOT_W = WIDTH - MARGIN_L - MARGIN_R
PLOT_H = HEIGHT - MARGIN_T - MARGIN_B

CPS_COLOR = "#1f77b4"
HIT_COLOR = "#d62728"


def load_records(path):
    records = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue  # tolerate a torn append from a cancelled run
                if isinstance(rec.get("configs_per_sec"), (int, float)):
                    records.append(rec)
    except OSError:
        pass
    return records


def svg_header(title):
    return [
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{WIDTH}" height="{HEIGHT}" '
        f'viewBox="0 0 {WIDTH} {HEIGHT}" font-family="monospace" font-size="12">',
        f'<rect width="{WIDTH}" height="{HEIGHT}" fill="white"/>',
        f'<text x="{WIDTH / 2}" y="20" text-anchor="middle" font-size="14">{title}</text>',
    ]


def placeholder_svg(msg):
    parts = svg_header("fgpm sweep perf trajectory")
    parts.append(
        f'<text x="{WIDTH / 2}" y="{HEIGHT / 2}" text-anchor="middle" '
        f'fill="#888">{msg}</text>'
    )
    parts.append("</svg>")
    return "\n".join(parts) + "\n"


def x_of(i, n):
    if n <= 1:
        return MARGIN_L + PLOT_W / 2
    return MARGIN_L + PLOT_W * i / (n - 1)


def y_of(v, lo, hi):
    if hi <= lo:
        return MARGIN_T + PLOT_H / 2
    return MARGIN_T + PLOT_H * (1.0 - (v - lo) / (hi - lo))


def polyline(points, color, dashed=False):
    pts = " ".join(f"{px:.1f},{py:.1f}" for px, py in points)
    dash = ' stroke-dasharray="6,4"' if dashed else ""
    return f'<polyline points="{pts}" fill="none" stroke="{color}" stroke-width="2"{dash}/>'


def render(records):
    n = len(records)
    cps = [float(r["configs_per_sec"]) for r in records]
    hit = [float(r.get("cache_hit_rate") or 0.0) for r in records]
    cps_hi = max(cps) * 1.1 or 1.0

    parts = svg_header(f"fgpm sweep perf trajectory ({n} runs)")
    # frame + horizontal grid with dual-axis tick labels
    parts.append(
        f'<rect x="{MARGIN_L}" y="{MARGIN_T}" width="{PLOT_W}" height="{PLOT_H}" '
        f'fill="none" stroke="#ccc"/>'
    )
    for frac in (0.0, 0.25, 0.5, 0.75, 1.0):
        gy = MARGIN_T + PLOT_H * (1.0 - frac)
        parts.append(
            f'<line x1="{MARGIN_L}" y1="{gy:.1f}" x2="{MARGIN_L + PLOT_W}" y2="{gy:.1f}" '
            f'stroke="#eee"/>'
        )
        parts.append(
            f'<text x="{MARGIN_L - 6}" y="{gy + 4:.1f}" text-anchor="end" '
            f'fill="{CPS_COLOR}">{cps_hi * frac:.0f}</text>'
        )
        parts.append(
            f'<text x="{MARGIN_L + PLOT_W + 6}" y="{gy + 4:.1f}" text-anchor="start" '
            f'fill="{HIT_COLOR}">{frac:.2f}</text>'
        )
    # axis titles + legend
    parts.append(
        f'<text x="{MARGIN_L}" y="{HEIGHT - 10}" fill="{CPS_COLOR}">configs/sec (left)</text>'
    )
    parts.append(
        f'<text x="{MARGIN_L + PLOT_W}" y="{HEIGHT - 10}" text-anchor="end" '
        f'fill="{HIT_COLOR}">cache hit-rate (right, dashed)</text>'
    )
    parts.append(
        f'<text x="{WIDTH / 2}" y="{HEIGHT - 10}" text-anchor="middle" fill="#666">run index '
        f"(oldest → newest)</text>"
    )

    cps_pts = [(x_of(i, n), y_of(v, 0.0, cps_hi)) for i, v in enumerate(cps)]
    hit_pts = [(x_of(i, n), y_of(v, 0.0, 1.0)) for i, v in enumerate(hit)]
    parts.append(polyline(cps_pts, CPS_COLOR))
    parts.append(polyline(hit_pts, HIT_COLOR, dashed=True))
    for px, py in cps_pts:
        parts.append(f'<circle cx="{px:.1f}" cy="{py:.1f}" r="2.5" fill="{CPS_COLOR}"/>')
    for px, py in hit_pts:
        parts.append(f'<circle cx="{px:.1f}" cy="{py:.1f}" r="2.5" fill="{HIT_COLOR}"/>')
    # annotate the newest run
    last = records[-1]
    label = f"{cps[-1]:.0f} cfg/s · hit {hit[-1]:.2f} · {str(last.get('sha', ''))[:8]}"
    parts.append(
        f'<text x="{MARGIN_L + PLOT_W}" y="{MARGIN_T - 8}" text-anchor="end" '
        f'fill="#333">latest: {label}</text>'
    )
    parts.append("</svg>")
    return "\n".join(parts) + "\n"


def main(argv):
    if len(argv) != 3:
        print(f"usage: {argv[0]} BENCH_trajectory.jsonl OUT.svg", file=sys.stderr)
        sys.exit(2)
    records = load_records(argv[1])
    if not records:
        svg = placeholder_svg(f"no trajectory records in {argv[1]} yet")
        print(f"plot-trajectory: no records in {argv[1]}; wrote placeholder {argv[2]}")
    else:
        svg = render(records)
        print(f"plot-trajectory: rendered {len(records)} runs -> {argv[2]}")
    with open(argv[2], "w") as f:
        f.write(svg)


if __name__ == "__main__":
    main(sys.argv)
