#!/usr/bin/env python3
"""CI chaos-smoke client for the resilient fgpm coordinator.

Usage:
    python3 ci/chaos_smoke.py chaos --addr 127.0.0.1:7272 \
        --model llemma7b --platform perlmutter --gpus 16 --schedule all
    python3 ci/chaos_smoke.py drained --log serve.log --cache-dir .fgpm-chaos-cache

Phase `chaos` (run against a live `fgpm serve`):

  1. baseline   — one full streamed sweep over a raw socket; its RAW
                  response lines are the byte-level reference;
  2. disconnect — start the same sweep, read two rows, then sever the
                  connection mid-stream; the server must survive (a
                  fresh connection still answers `ping`);
  3. resume     — re-request with `resume_from` k in {0, 1, n/2, n}:
                  every response must be the byte-identical suffix of
                  the baseline, and the summary must acknowledge k;
  4. stats      — the server counted the resumed sweeps.

Phase `drained` (after SIGTERM has been delivered and the process has
exited):

  5. the serve log carries the final `fgpm drained:` line with the
     persisted-cache confirmation;
  6. the persisted op-cache file exists, carries the FGPMOPC\\x01 magic,
     and is at least header-sized (24 bytes) — never half-written.

Exit code 0 = all checks passed; 1 = any violation.

The byte-identity check of the CLI's `--remote --retries` path against
the local table lives in the workflow itself (`diff` of the rendered
tables), mirroring the service-smoke job.
"""

import argparse
import glob
import json
import os
import socket
import struct
import sys

OPCACHE_MAGIC = b"FGPMOPC\x01"
OPCACHE_HEADER_BYTES = 24


def fail(msg):
    print(f"chaos-smoke: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def connect(addr, timeout=600.0):
    host, port = addr.rsplit(":", 1)
    return socket.create_connection((host, int(port)), timeout=timeout)


def sweep_request(args, resume_from=None):
    req = {
        "cmd": "sweep",
        "spec": {
            "model": args.model,
            "platform": args.platform,
            "gpus": args.gpus,
            "schedules": (
                ["1f1b", "gpipe", "interleaved:2", "zb-h1"]
                if args.schedule == "all"
                else [args.schedule]
            ),
        },
    }
    if resume_from is not None:
        req["resume_from"] = resume_from
    return req


def stream_sweep(addr, req):
    """Send one sweep request; return (raw_row_lines, summary_obj).

    Lines are kept VERBATIM (newline included) so suffix comparisons are
    byte-exact, not merely value-equal.
    """
    sock = connect(addr)
    sock.sendall((json.dumps(req) + "\n").encode())
    rfile = sock.makefile("rb")
    rows = []
    while True:
        line = rfile.readline()
        if not line:
            fail(f"server closed the stream before the summary (request {req})")
        msg = json.loads(line)
        if "error" in msg:
            fail(f"sweep error for {req}: {msg['error']}")
        if "summary" in msg:
            sock.close()
            return rows, msg["summary"]
        if "row" not in msg:
            fail(f"unexpected sweep line: {msg}")
        rows.append(line)


def single_request(addr, obj):
    sock = connect(addr, timeout=30.0)
    sock.sendall((json.dumps(obj) + "\n").encode())
    line = sock.makefile("rb").readline()
    sock.close()
    if not line:
        fail(f"no response for {obj}")
    return json.loads(line)


def phase_chaos(args):
    # 1. baseline: the reference byte stream
    reference, summary = stream_sweep(args.addr, sweep_request(args))
    if len(reference) < 3:
        fail(f"baseline sweep streamed only {len(reference)} rows")
    if "resume_from" in summary:
        fail(f"un-resumed summary must not acknowledge a resume: {summary}")
    print(f"chaos-smoke: baseline ok ({len(reference)} rows)")

    # 2. kill a connection mid-sweep: read two rows, then sever the
    # socket abruptly (RST via SO_LINGER 0, the rudest realistic cut)
    sock = connect(args.addr)
    sock.sendall((json.dumps(sweep_request(args)) + "\n").encode())
    rfile = sock.makefile("rb")
    for i in range(2):
        if not rfile.readline():
            fail(f"mid-sweep stream ended at row {i}")
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER, struct.pack("ii", 1, 0))
    sock.close()
    pong = single_request(args.addr, {"cmd": "ping"})
    if pong.get("ok") is not True:
        fail(f"server unhealthy after mid-sweep disconnect: {pong}")
    print("chaos-smoke: server survived a mid-sweep disconnect")

    # 3. resumed streams are byte-identical suffixes
    n = len(reference)
    for k in sorted({0, 1, n // 2, n}):
        rows, summary = stream_sweep(args.addr, sweep_request(args, resume_from=k))
        if rows != reference[k:]:
            fail(f"resume_from={k}: response is not the byte-identical suffix")
        ack = summary.get("resume_from")
        want = k if k > 0 else None
        if ack != want:
            fail(f"resume_from={k}: summary acknowledged {ack!r}, want {want!r}")
    print("chaos-smoke: resumed streams are byte-identical suffixes")

    # 4. the server counted the client retries
    stats = single_request(args.addr, {"cmd": "stats"})
    if "error" in stats:
        fail(f"stats error: {stats['error']}")
    if not stats.get("retries", 0) >= 3:
        fail(f"stats must count the resumed requests as retries: {stats}")
    if not stats.get("resumed_sweeps", 0) >= 3:
        fail(f"stats must count completed resumed sweeps: {stats}")
    print(
        f"chaos-smoke: stats ok (retries {stats['retries']:.0f}, "
        f"resumed_sweeps {stats['resumed_sweeps']:.0f})"
    )


def phase_drained(args):
    # 5. the drain left its final log line
    with open(args.log, encoding="utf-8", errors="replace") as f:
        log = f.read()
    drain_lines = [ln for ln in log.splitlines() if ln.startswith("fgpm drained:")]
    if not drain_lines:
        fail(f"no 'fgpm drained:' line in {args.log}:\n{log}")
    line = drain_lines[-1]
    if "0 aborted" not in line:
        fail(f"drain aborted in-flight work: {line}")
    if "op cache persisted" not in line:
        fail(f"drain line missing the persist confirmation: {line}")
    print(f"chaos-smoke: drain ok ({line})")

    # 6. the persisted cache file is whole
    paths = sorted(glob.glob(os.path.join(args.cache_dir, "opcache_*.bin")))
    if not paths:
        fail(f"no persisted op-cache file under {args.cache_dir}")
    for path in paths:
        with open(path, "rb") as f:
            blob = f.read()
        if len(blob) < OPCACHE_HEADER_BYTES:
            fail(f"{path}: {len(blob)} bytes is smaller than the header")
        if not blob.startswith(OPCACHE_MAGIC):
            fail(f"{path}: bad magic {blob[:8]!r}")
        print(f"chaos-smoke: persisted cache ok ({path}, {len(blob)} bytes)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("phase", choices=["chaos", "drained"])
    ap.add_argument("--addr", default="127.0.0.1:7272")
    ap.add_argument("--model", default="llemma7b")
    ap.add_argument("--platform", default="perlmutter")
    ap.add_argument("--gpus", type=int, default=16)
    ap.add_argument("--schedule", default="all")
    ap.add_argument("--log", default="serve.log")
    ap.add_argument("--cache-dir", default=".fgpm-chaos-cache")
    args = ap.parse_args()
    if args.phase == "chaos":
        phase_chaos(args)
    else:
        phase_drained(args)
    print(f"chaos-smoke: phase '{args.phase}' passed")


if __name__ == "__main__":
    main()
