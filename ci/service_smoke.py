#!/usr/bin/env python3
"""CI service-smoke client for the fgpm coordinator.

Usage:
    python3 ci/service_smoke.py --addr 127.0.0.1:7171 --local local_sweep.txt \
        --model llemma7b --platform perlmutter --gpus 16 --schedule all

Drives the JSON-lines TCP protocol end to end against a running
`fgpm serve`:

  1. `ping`    — liveness;
  2. `predict` — one end-to-end configuration prediction;
  3. `stats`   — metrics + op-cache tier counters present and sane;
  4. `sweep`   — one STREAMED sweep, rows-then-summary framing checked
                 (incl. the per-phase prefetch/compose timings);
  5. parity    — the streamed rows match the table `fgpm sweep` printed
                 locally on the same spec (`--local`): same labels in the
                 same ranked order, seconds agreeing at the table's
                 printed precision;
  6. `stats`   — the latency histograms saw the predict and the sweep
                 (non-zero p50/p99 quantiles);
  7. `metrics` — the Prometheus text exposition parses, carries TYPE
                 lines, and its histogram buckets are cumulative.

Exit code 0 = all checks passed; 1 = any mismatch/protocol violation.
"""

import argparse
import json
import re
import socket
import sys

ROW_RE = re.compile(r"^\s*\d+\.\s+(\S+)\s+([0-9.]+) s\s+([0-9.]+) GiB/GPU")


def fail(msg):
    print(f"service-smoke: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


class Client:
    def __init__(self, addr, timeout=600.0):
        host, port = addr.rsplit(":", 1)
        self.sock = socket.create_connection((host, int(port)), timeout=timeout)
        self.rfile = self.sock.makefile("r", encoding="utf-8")

    def send(self, obj):
        self.sock.sendall((json.dumps(obj) + "\n").encode())

    def recv(self):
        line = self.rfile.readline()
        if not line:
            fail("server closed the connection")
        try:
            return json.loads(line)
        except json.JSONDecodeError as e:
            fail(f"unparseable server line {line!r}: {e}")

    def request(self, obj):
        self.send(obj)
        resp = self.recv()
        if "error" in resp:
            fail(f"server error for {obj}: {resp['error']}")
        return resp

    def recv_text_block(self):
        """Read a raw multi-line response terminated by a blank line
        (the `metrics` command's Prometheus exposition framing)."""
        lines = []
        while True:
            line = self.rfile.readline()
            if not line:
                fail("server closed the connection mid text block")
            if line == "\n":
                return lines
            lines.append(line.rstrip("\n"))


def parse_local_table(path):
    rows = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            m = ROW_RE.match(line)
            if m:
                rows.append((m.group(1), float(m.group(2)), float(m.group(3))))
    if not rows:
        fail(f"no sweep rows found in {path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--addr", required=True)
    ap.add_argument("--local", required=True, help="output of the local `fgpm sweep` run")
    ap.add_argument("--model", default="llemma7b")
    ap.add_argument("--platform", default="perlmutter")
    ap.add_argument("--gpus", type=int, default=16)
    ap.add_argument("--schedule", default="all")
    args = ap.parse_args()

    c = Client(args.addr)

    # 1. ping
    pong = c.request({"cmd": "ping"})
    if pong.get("ok") is not True:
        fail(f"bad ping response: {pong}")
    print("service-smoke: ping ok")

    # 2. predict
    pred = c.request(
        {"cmd": "predict", "model": args.model, "parallel": "2-2-2", "platform": args.platform}
    )
    if not (isinstance(pred.get("total_s"), (int, float)) and pred["total_s"] > 0):
        fail(f"bad predict response: {pred}")
    print(f"service-smoke: predict ok ({pred['label']}: {pred['total_s']:.2f}s)")

    # 3. stats
    stats = c.request({"cmd": "stats"})
    for field in (
        "queries",
        "predictions",
        "sweeps",
        "op_cache_hits",
        "op_cache_disk_hits",
        "op_cache_misses",
        "op_cache_hit_rate",
    ):
        if field not in stats:
            fail(f"stats missing '{field}': {stats}")
    if not (0.0 <= stats["op_cache_hit_rate"] <= 1.0):
        fail(f"op_cache_hit_rate out of range: {stats}")
    print("service-smoke: stats ok")

    # 4. streamed sweep
    schedules = (
        ["1f1b", "gpipe", "interleaved:2", "zb-h1"]
        if args.schedule == "all"
        else [args.schedule]
    )
    c.send(
        {
            "cmd": "sweep",
            "spec": {
                "model": args.model,
                "platform": args.platform,
                "gpus": args.gpus,
                "schedules": schedules,
            },
        }
    )
    rows, summary = [], None
    while True:
        msg = c.recv()
        if "error" in msg:
            fail(f"sweep error: {msg['error']}")
        if "row" in msg:
            if summary is not None:
                fail("row after summary")
            r = msg["row"]
            rows.append((r["label"], r["total_us"], r["mem_gib"]))
            continue
        if "summary" in msg:
            summary = msg["summary"]
            break
        fail(f"unexpected sweep line: {msg}")
    if summary["configs"] != len(rows):
        fail(f"summary configs {summary['configs']} != streamed rows {len(rows)}")
    if not rows:
        fail("sweep streamed no rows")
    ranked = [r[1] for r in rows]
    if ranked != sorted(ranked):
        fail("rows not ranked fastest-first")
    print(
        f"service-smoke: sweep ok ({len(rows)} rows, "
        f"{summary['configs_per_sec']:.0f} configs/s, "
        f"hit-rate {summary['cache_hit_rate']:.2f} "
        f"[mem {summary['cache_memory_hit_rate']:.2f} / disk {summary['cache_disk_hit_rate']:.2f}])"
    )

    # the sweep summary attributes its wall-clock to engine phases
    for key in ("prefetch_us", "compose_us"):
        if not (isinstance(summary.get(key), (int, float)) and summary[key] > 0):
            fail(f"summary missing positive '{key}': {summary}")
    print(
        f"service-smoke: phase timings ok (prefetch {summary['prefetch_us']:.0f}us, "
        f"compose {summary['compose_us']:.0f}us, bound {summary.get('bound_us', 0.0):.0f}us)"
    )

    # 5. parity with the local run
    local = parse_local_table(args.local)
    if len(local) != len(rows):
        fail(f"local table has {len(local)} rows, stream has {len(rows)}")
    for i, ((l_label, l_secs, l_mem), (r_label, r_us, r_mem)) in enumerate(zip(local, rows)):
        if l_label != r_label:
            fail(f"row {i + 1}: local label {l_label!r} != remote {r_label!r}")
        if abs(l_secs - r_us / 1e6) > 0.005 + 1e-9:
            fail(f"row {i + 1} ({l_label}): local {l_secs}s vs remote {r_us / 1e6}s")
        if abs(l_mem - r_mem) > 0.05 + 1e-9:
            fail(f"row {i + 1} ({l_label}): local {l_mem} GiB vs remote {r_mem} GiB")
    print(f"service-smoke: parity ok — {len(rows)} remote rows match the local sweep")

    # 6. the latency histograms saw the predict and the sweep
    stats = c.request({"cmd": "stats"})
    for prefix in ("predict", "sweep"):
        for q in ("p50", "p99"):
            key = f"{prefix}_{q}_us"
            if not (isinstance(stats.get(key), (int, float)) and stats[key] > 0):
                fail(f"stats missing positive '{key}' after serving a {prefix}: {stats}")
    print(
        f"service-smoke: latency quantiles ok (predict p50 {stats['predict_p50_us']:.0f}us "
        f"p99 {stats['predict_p99_us']:.0f}us, sweep p50 {stats['sweep_p50_us']:.0f}us)"
    )

    # 7. Prometheus text exposition
    c.send({"cmd": "metrics", "format": "prometheus"})
    text = c.recv_text_block()
    check_prometheus(text)
    print(f"service-smoke: prometheus ok ({len(text)} exposition lines)")


def check_prometheus(lines):
    """Minimal Prometheus text-format validation: every sample line is
    `name{labels} value`, TYPE lines cover the core metrics, and each
    histogram's buckets are cumulative with a +Inf cap matching _count."""
    if lines and lines[0].startswith("{"):
        fail(f"metrics returned an error: {lines[0]}")
    sample_re = re.compile(r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+(-?[0-9.e+]+|\+Inf)$')
    types, samples = {}, []
    for line in lines:
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in ("counter", "gauge", "histogram"):
                fail(f"malformed TYPE line: {line!r}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        m = sample_re.match(line)
        if not m:
            fail(f"unparseable exposition line: {line!r}")
        samples.append((m.group(1), m.group(2), float(m.group(3))))
    for name in ("fgpm_queries_total", "fgpm_predictions_total", "fgpm_sweeps_total"):
        if types.get(name) != "counter":
            fail(f"missing counter TYPE for {name} (got {types})")
    values = {name: v for name, labels, v in samples if labels is None}
    if values.get("fgpm_predictions_total", 0) < 1 or values.get("fgpm_sweeps_total", 0) < 1:
        fail(f"served commands not visible in exposition: {values}")
    for hist in ("fgpm_predict_latency_us", "fgpm_sweep_latency_us"):
        if types.get(hist) != "histogram":
            fail(f"missing histogram TYPE for {hist} (got {types})")
        buckets = [
            (labels, v) for name, labels, v in samples if name == f"{hist}_bucket"
        ]
        if not buckets or buckets[-1][0] != '{le="+Inf"}':
            fail(f"{hist}: bucket list missing or not capped by +Inf: {buckets}")
        cum = [v for _, v in buckets]
        if cum != sorted(cum):
            fail(f"{hist}: buckets not cumulative: {cum}")
        if cum[-1] != values.get(f"{hist}_count"):
            fail(f"{hist}: +Inf bucket {cum[-1]} != _count {values.get(f'{hist}_count')}")


if __name__ == "__main__":
    main()
