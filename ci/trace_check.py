#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file emitted by `fgpm trace` or
`--trace-out` (stdlib only — runs in bare CI images).

Checks:
  - the file parses as JSON and `traceEvents` is a non-empty list
  - every event carries `ph`, `ts`, `pid`, `tid`
  - every `X` (complete) event has a non-negative `dur`
  - `X` events are time-sorted within each (pid, tid) track
  - `s`/`f` flow arrows come in exactly-matched id pairs

Usage: trace_check.py <trace.json> [<trace.json> ...]
Exits non-zero with a diagnostic on the first failure.
"""
import json
import sys


def fail(path, msg):
    print(f"FAIL {path}: {msg}", file=sys.stderr)
    sys.exit(1)


def check(path):
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(path, f"unreadable or invalid JSON: {e}")

    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(path, "traceEvents missing, not a list, or empty")

    last_ts = {}  # (pid, tid) -> last X-event ts
    flows = {"s": {}, "f": {}}  # ph -> id -> count
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            fail(path, f"event {i} is not an object")
        for key in ("ph", "ts", "pid", "tid"):
            if key not in ev:
                fail(path, f"event {i} missing '{key}': {ev}")
        ph = ev["ph"]
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                fail(path, f"event {i}: X event with bad dur {dur!r}")
            track = (ev["pid"], ev["tid"])
            if ev["ts"] < last_ts.get(track, float("-inf")):
                fail(path, f"event {i}: X events not time-sorted on track {track}")
            last_ts[track] = ev["ts"]
        elif ph in flows:
            fid = ev.get("id")
            if fid is None:
                fail(path, f"event {i}: flow event without id")
            flows[ph][fid] = flows[ph].get(fid, 0) + 1

    if flows["s"] != flows["f"]:
        starts = set(flows["s"]) - set(flows["f"])
        ends = set(flows["f"]) - set(flows["s"])
        fail(path, f"unpaired flow arrows (s-only ids {sorted(starts)[:5]}, "
                   f"f-only ids {sorted(ends)[:5]})")

    n_x = sum(1 for e in events if e.get("ph") == "X")
    print(f"OK {path}: {len(events)} events ({n_x} slices, "
          f"{sum(flows['s'].values())} flows, {len(last_ts)} tracks)")


def main(argv):
    if len(argv) < 2:
        print(__doc__, file=sys.stderr)
        return 2
    for path in argv[1:]:
        check(path)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
