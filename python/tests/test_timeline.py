"""L2 timeline graph (eq. 7) vs scalar reference + analytic cases."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref, shapes


def random_timeline_inputs(rng):
    fwd = rng.uniform(0, 100, size=(shapes.C, shapes.S)).astype(np.float32)
    bwd = rng.uniform(0, 200, size=(shapes.C, shapes.S)).astype(np.float32)
    update = rng.uniform(0, 50, size=(shapes.C, shapes.S)).astype(np.float32)
    dp_first = rng.uniform(0, 30, size=(shapes.C,)).astype(np.float32)
    micro = rng.integers(1, 32, size=(shapes.C,)).astype(np.float32)
    stages = rng.integers(1, shapes.S + 1, size=(shapes.C,)).astype(np.float32)
    mask = np.zeros((shapes.C, shapes.S), dtype=np.float32)
    for i, s in enumerate(stages.astype(int)):
        mask[i, :s] = 1.0
    return fwd, bwd, mask, dp_first, update, micro, stages


class TestTimeline:
    def test_matches_ref(self):
        rng = np.random.default_rng(7)
        args = random_timeline_inputs(rng)
        (got,) = model.timeline_batch(*args)
        want = ref.timeline_ref(*args)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)

    def test_single_stage_degenerates_to_serial(self):
        """S=1, M micro-batches: runtime = M*(fwd+bwd) + dp + update."""
        fwd = np.zeros((shapes.C, shapes.S), dtype=np.float32)
        bwd = np.zeros_like(fwd)
        update = np.zeros_like(fwd)
        mask = np.zeros_like(fwd)
        fwd[:, 0], bwd[:, 0], update[:, 0], mask[:, 0] = 3.0, 5.0, 2.0, 1.0
        dp_first = np.full(shapes.C, 7.0, dtype=np.float32)
        micro = np.full(shapes.C, 16.0, dtype=np.float32)
        stages = np.ones(shapes.C, dtype=np.float32)
        (got,) = model.timeline_batch(fwd, bwd, mask, dp_first, update,
                                      micro, stages)
        np.testing.assert_allclose(np.asarray(got), 16 * 8.0 + 7.0 + 2.0)

    def test_slowest_stage_dominates(self):
        """Doubling a non-max stage time does not change the runtime."""
        rng = np.random.default_rng(3)
        args = list(random_timeline_inputs(rng))
        (base,) = model.timeline_batch(*args)
        fwd = args[0].copy()
        i = 0
        s = int(args[6][i])
        if s >= 2:
            row = fwd[i, :s]
            jmin = int(np.argmin(row))
            row[jmin] = row[jmin] * 0.5  # shrink the min — still not the max
            args[0] = fwd
            (got,) = model.timeline_batch(*args)
            np.testing.assert_allclose(np.asarray(got)[i],
                                       np.asarray(base)[i], rtol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_hypothesis_matches_ref(self, seed):
        rng = np.random.default_rng(seed)
        args = random_timeline_inputs(rng)
        (got,) = model.timeline_batch(*args)
        want = ref.timeline_ref(*args)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1),
           extra=st.floats(min_value=0.1, max_value=100.0))
    def test_monotone_in_max_fwd(self, seed, extra):
        """Increasing the slowest stage's fwd time never decreases runtime."""
        rng = np.random.default_rng(seed)
        args = list(random_timeline_inputs(rng))
        (base,) = model.timeline_batch(*args)
        fwd = args[0].copy()
        fwd[:, 0] += np.float32(extra)
        args2 = list(args)
        args2[0] = fwd
        (got,) = model.timeline_batch(*args2)
        assert np.all(np.asarray(got) >= np.asarray(base) - 1e-4)
