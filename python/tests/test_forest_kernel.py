"""Pallas forest kernel vs scalar numpy oracle — the core L1 correctness
signal. Hypothesis sweeps forest shapes, tree depths, and query dtypes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import forest, ref, shapes

RNG = np.random.default_rng(0)


def random_forest_tensors(rng, t_count, n_count, f_count, depth, n_trees):
    """Build a random but *valid* flattened forest: complete binary trees of
    `depth` levels, children strictly after parents, leaves marked LEAF."""
    node_feat = np.full((t_count, n_count), shapes.LEAF, dtype=np.int32)
    thresh = np.zeros((t_count, n_count), dtype=np.float32)
    left = np.zeros((t_count, n_count), dtype=np.int32)
    right = np.zeros((t_count, n_count), dtype=np.int32)
    value = np.zeros((t_count, n_count), dtype=np.float32)
    tree_w = np.zeros(t_count, dtype=np.float32)

    for t in range(n_trees):
        tree_w[t] = 1.0 / n_trees
        # level-order complete tree: internal nodes 0..2^(depth-1)-2
        n_internal = 2 ** (depth - 1) - 1 if depth > 1 else 0
        n_total = 2 ** depth - 1
        assert n_total <= n_count
        for i in range(n_internal):
            node_feat[t, i] = rng.integers(0, f_count)
            thresh[t, i] = rng.normal()
            left[t, i] = 2 * i + 1
            right[t, i] = 2 * i + 2
        for i in range(n_internal, n_total):
            value[t, i] = rng.normal()
        if n_internal == 0:
            value[t, 0] = rng.normal()
    return node_feat, thresh, left, right, value, tree_w


def run_both(feat, tensors, depth=shapes.D):
    got = np.asarray(forest.forest_infer(feat, *tensors, depth=depth))
    want = ref.forest_infer_ref(feat, *tensors, depth=depth)
    return got, want


class TestForestKernelFixed:
    def test_single_stump(self):
        """One depth-1 tree (a leaf only) predicts its constant."""
        t = random_forest_tensors(RNG, shapes.T, shapes.N, shapes.F, 1, 1)
        feat = RNG.normal(size=(shapes.BB, shapes.F)).astype(np.float32)
        got, want = run_both(feat, t)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_single_split(self):
        """Hand-built depth-2 tree: x[3] <= 0 -> 10 else -5."""
        node_feat = np.full((shapes.T, shapes.N), shapes.LEAF, dtype=np.int32)
        thresh = np.zeros((shapes.T, shapes.N), dtype=np.float32)
        left = np.zeros((shapes.T, shapes.N), dtype=np.int32)
        right = np.zeros((shapes.T, shapes.N), dtype=np.int32)
        value = np.zeros((shapes.T, shapes.N), dtype=np.float32)
        tree_w = np.zeros(shapes.T, dtype=np.float32)
        node_feat[0, 0], thresh[0, 0] = 3, 0.0
        left[0, 0], right[0, 0] = 1, 2
        value[0, 1], value[0, 2] = 10.0, -5.0
        tree_w[0] = 1.0
        feat = np.zeros((shapes.BB, shapes.F), dtype=np.float32)
        feat[:, 3] = np.linspace(-1, 1, shapes.BB)
        got = np.asarray(forest.forest_infer(
            feat, node_feat, thresh, left, right, value, tree_w))
        want = np.where(feat[:, 3] <= 0.0, 10.0, -5.0)
        np.testing.assert_allclose(got, want)

    def test_boundary_goes_left(self):
        """x[f] == thresh must take the LEFT branch (<=)."""
        t = random_forest_tensors(RNG, shapes.T, shapes.N, shapes.F, 2, 1)
        node_feat, thresh, left, right, value, tree_w = t
        feat = np.zeros((shapes.BB, shapes.F), dtype=np.float32)
        f0 = node_feat[0, 0]
        feat[:, f0] = thresh[0, 0]
        got, want = run_both(feat, t)
        np.testing.assert_allclose(got, want, rtol=1e-6)
        np.testing.assert_allclose(got, value[0, left[0, 0]] * tree_w[0],
                                   rtol=1e-6)

    def test_full_padded_shapes(self):
        """The exact AOT shapes (B=256, T=128) round-trip."""
        t = random_forest_tensors(RNG, shapes.T, shapes.N, shapes.F, 6, shapes.T)
        feat = RNG.normal(size=(shapes.B, shapes.F)).astype(np.float32)
        got, want = run_both(feat, t)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-4)

    def test_zero_weight_trees_ignored(self):
        """Padding trees (w=0) contribute nothing even with garbage nodes."""
        t = list(random_forest_tensors(RNG, shapes.T, shapes.N, shapes.F, 4, 8))
        t[5] = t[5].copy()
        # poison every tree's values, then zero all weights but tree 0
        w = np.zeros(shapes.T, dtype=np.float32)
        w[0] = 1.0
        t[5] = w
        feat = RNG.normal(size=(shapes.BB, shapes.F)).astype(np.float32)
        got, want = run_both(feat, tuple(t))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestForestKernelHypothesis:
    @settings(max_examples=20, deadline=None)
    @given(
        depth=st.integers(min_value=1, max_value=8),
        n_trees=st.integers(min_value=1, max_value=16),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref_across_shapes(self, depth, n_trees, seed):
        rng = np.random.default_rng(seed)
        t = random_forest_tensors(rng, shapes.T, shapes.N, shapes.F,
                                  depth, n_trees)
        feat = rng.normal(size=(shapes.BB, shapes.F)).astype(np.float32)
        got, want = run_both(feat, t)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1),
           scale=st.sampled_from([1e-3, 1.0, 1e3, 1e6]))
    def test_feature_scale_invariance_of_structure(self, seed, scale):
        """Thresholds/features co-scaled -> identical routing decisions."""
        rng = np.random.default_rng(seed)
        t = random_forest_tensors(rng, shapes.T, shapes.N, shapes.F, 5, 4)
        node_feat, thresh, left, right, value, tree_w = t
        feat = rng.normal(size=(shapes.BB, shapes.F)).astype(np.float32)
        base = np.asarray(forest.forest_infer(
            feat, node_feat, thresh, left, right, value, tree_w))
        scaled = np.asarray(forest.forest_infer(
            (feat * scale).astype(np.float32), node_feat,
            (thresh * scale).astype(np.float32), left, right, value, tree_w))
        np.testing.assert_allclose(base, scaled, rtol=1e-3, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(block=st.sampled_from([32, 64, 128, 256]),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_block_size_invariance(self, block, seed):
        """Grid/block decomposition must not change results."""
        rng = np.random.default_rng(seed)
        t = random_forest_tensors(rng, shapes.T, shapes.N, shapes.F, 5, 8)
        feat = rng.normal(size=(shapes.B, shapes.F)).astype(np.float32)
        a = np.asarray(forest.forest_infer(feat, *t, block_b=block))
        b = np.asarray(forest.forest_infer(feat, *t, block_b=shapes.B))
        np.testing.assert_allclose(a, b, rtol=1e-6)
