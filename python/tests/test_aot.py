"""AOT lowering smoke tests: HLO text artifacts exist, parse-ably shaped,
and the manifest matches the shape constants."""

import json

from compile import aot
from compile.kernels import shapes


class TestAot:
    def test_lower_all_produces_hlo_text(self):
        arts = aot.lower_all()
        assert set(arts) == {"forest_infer.hlo.txt", "timeline.hlo.txt"}
        for name, text in arts.items():
            assert "HloModule" in text, name
            assert "ROOT" in text, name
            assert len(text) > 500, name

    def test_forest_hlo_mentions_padded_shapes(self):
        text = aot.lower_all()["forest_infer.hlo.txt"]
        assert f"f32[{shapes.B},{shapes.F}]" in text
        assert f"s32[{shapes.T},{shapes.N}]" in text
        assert f"f32[{shapes.B}]" in text

    def test_timeline_hlo_mentions_padded_shapes(self):
        text = aot.lower_all()["timeline.hlo.txt"]
        assert f"f32[{shapes.C},{shapes.S}]" in text
        assert f"f32[{shapes.C}]" in text

    def test_manifest_consistent(self):
        m = aot.manifest()
        assert m["forest"]["batch"] == shapes.B
        assert m["forest"]["trees"] == shapes.T
        assert m["forest"]["nodes"] == shapes.N
        assert m["forest"]["depth"] == shapes.D
        assert m["timeline"]["configs"] == shapes.C
        assert m["timeline"]["stages"] == shapes.S
        json.dumps(m)  # serializable
