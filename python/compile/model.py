"""Layer-2 JAX compute graphs, AOT-lowered by aot.py and executed from rust.

Two graphs are exported:

1. `forest_infer_padded` — per-operator regressor inference. One executable
   serves every (platform, operator) forest: the forest tensors are runtime
   INPUTS (not baked constants), so the rust coordinator feeds whichever
   flattened forest the routed queries need. Calls the Layer-1 Pallas
   kernel (kernels/forest.py).

2. `timeline_batch` — the paper's eq. (7) end-to-end composition, batched
   over C configurations so a parallelism sweep amortizes one execution.

Shapes are the padded AOT constants from kernels/shapes.py; the rust side
reads them from artifacts/manifest.json.
"""

import jax.numpy as jnp

from .kernels import forest, shapes


def forest_infer_padded(feat, node_feat, thresh, left, right, value, tree_w):
    """[B, F] queries x one padded forest -> [B] predictions.

    Regressors are trained on log1p(latency_us); the graph folds the
    inverse transform (expm1) so rust receives microseconds directly.
    """
    log_pred = forest.forest_infer(
        feat, node_feat, thresh, left, right, value, tree_w)
    return (jnp.expm1(log_pred),)


def timeline_batch(fwd, bwd, mask, dp_first, update, micro, stages):
    """Batched eq. (7).

    fwd, bwd, update: [C, S] per-stage times (mask-padded); mask: [C, S]
    in {0,1}; dp_first: [C] first-stage DP all-reduce; micro, stages: [C].
    Times are nonnegative, so masked maxima are plain max(x * mask).
    """
    mf = jnp.max(fwd * mask, axis=1)
    mb = jnp.max(bwd * mask, axis=1)
    mu = jnp.max(update * mask, axis=1)
    runtime = (micro - 1.0 + stages) * (mf + mb) + dp_first + mu
    return (runtime,)


def forest_example_args():
    """ShapeDtypeStructs for AOT lowering of forest_infer_padded."""
    import jax

    f32 = jnp.float32
    i32 = jnp.int32
    tn = (shapes.T, shapes.N)
    return (
        jax.ShapeDtypeStruct((shapes.B, shapes.F), f32),
        jax.ShapeDtypeStruct(tn, i32),
        jax.ShapeDtypeStruct(tn, f32),
        jax.ShapeDtypeStruct(tn, i32),
        jax.ShapeDtypeStruct(tn, i32),
        jax.ShapeDtypeStruct(tn, f32),
        jax.ShapeDtypeStruct((shapes.T,), f32),
    )


def timeline_example_args():
    """ShapeDtypeStructs for AOT lowering of timeline_batch."""
    import jax

    f32 = jnp.float32
    cs = (shapes.C, shapes.S)
    return (
        jax.ShapeDtypeStruct(cs, f32),
        jax.ShapeDtypeStruct(cs, f32),
        jax.ShapeDtypeStruct(cs, f32),
        jax.ShapeDtypeStruct((shapes.C,), f32),
        jax.ShapeDtypeStruct(cs, f32),
        jax.ShapeDtypeStruct((shapes.C,), f32),
        jax.ShapeDtypeStruct((shapes.C,), f32),
    )
