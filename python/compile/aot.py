"""AOT lowering: jax -> HLO TEXT artifacts consumed by the rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs (under --out, default ../artifacts):
  forest_infer.hlo.txt  Layer-1 Pallas forest inference (+expm1), padded
  timeline.hlo.txt      Layer-2 eq. (7) batched timeline aggregation
  manifest.json         the padded shape constants for the rust runtime

Python runs ONCE here; it is never on the request path.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import shapes


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    forest = jax.jit(model.forest_infer_padded).lower(*model.forest_example_args())
    timeline = jax.jit(model.timeline_batch).lower(*model.timeline_example_args())
    return {
        "forest_infer.hlo.txt": to_hlo_text(forest),
        "timeline.hlo.txt": to_hlo_text(timeline),
    }


def manifest() -> dict:
    return {
        "format": "hlo-text",
        "log_space": True,  # forests trained on log1p(us); expm1 in-graph
        "forest": {
            "batch": shapes.B,
            "block_b": shapes.BB,
            "features": shapes.F,
            "trees": shapes.T,
            "nodes": shapes.N,
            "depth": shapes.D,
            "leaf": shapes.LEAF,
            "inputs": ["feat", "node_feat", "thresh", "left", "right",
                       "value", "tree_w"],
        },
        "timeline": {
            "configs": shapes.C,
            "stages": shapes.S,
            "inputs": ["fwd", "bwd", "mask", "dp_first", "update", "micro",
                       "stages"],
        },
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    for name, text in lower_all().items():
        path = os.path.join(args.out, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars  {path}")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest(), f, indent=2)
    print(f"wrote manifest        {mpath}")


if __name__ == "__main__":
    main()
