"""Layer-1 Pallas kernels + pure-jnp/numpy references.

Shared AOT shape constants live in `shapes`; the rust runtime reads the
same values from artifacts/manifest.json.
"""

from . import shapes  # noqa: F401
