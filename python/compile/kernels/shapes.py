"""AOT shape constants shared by the Pallas kernel, the L2 model, the AOT
lowering, and (via artifacts/manifest.json) the rust runtime.

The forest-inference executable is compiled ONCE for these padded shapes;
every per-operator forest is exported (rust `forest::export`) into this
layout, and the coordinator's dynamic batcher pads query batches to B.
"""

# Forest inference ----------------------------------------------------------
B = 256   # query batch (padded by the L3 dynamic batcher)
BB = 64   # query block per grid step (B % BB == 0)
F = 8     # feature width (workload-representation vectors padded to F)
T = 128   # max trees per forest (unused trees get weight 0)
N = 1024  # max nodes per tree (row-padded)
D = 16    # traversal steps == max tree depth supported by the kernel

# Timeline aggregation (eq. 7) ----------------------------------------------
C = 64    # configs per timeline batch
S = 16    # max pipeline stages (mask-padded)

LEAF = -1  # node_feat value marking a leaf node
