"""Pure-numpy correctness oracles for the Pallas kernels.

`forest_infer_ref` walks every (query, tree) pair with explicit scalar
control flow — the "obviously correct" semantics the vectorized
level-synchronous kernel must match bit-for-bit (same f32 accumulation
order is NOT guaranteed, so tests use allclose with tight tolerances).
"""

import numpy as np


def forest_infer_ref(feat, node_feat, thresh, left, right, value, tree_w,
                     depth=None, leaf=-1):
    """Scalar reference: returns [B] predictions.

    Semantics: start at node 0 of each tree; while the node is internal,
    go left iff x[f] <= thresh else right; at a leaf, contribute
    tree_w[t] * value[leaf]. `depth` caps traversal (kernel runs exactly D
    steps); trees deeper than `depth` are a layout bug upstream.
    """
    feat = np.asarray(feat)
    b = feat.shape[0]
    t_count, _n = node_feat.shape
    max_steps = depth if depth is not None else node_feat.shape[1]
    out = np.zeros(b, dtype=np.float64)
    for i in range(b):
        for t in range(t_count):
            if tree_w[t] == 0.0:
                # Padding tree: the kernel still dots value*0 — identical.
                continue
            idx = 0
            for _ in range(max_steps):
                f = node_feat[t, idx]
                if f == leaf:
                    break
                if feat[i, f] <= thresh[t, idx]:
                    idx = left[t, idx]
                else:
                    idx = right[t, idx]
            out[i] += float(tree_w[t]) * float(value[t, idx])
    return out.astype(np.float32)


def timeline_ref(fwd, bwd, mask, dp_first, update, micro, stages):
    """Scalar reference of eq. (7): returns [C] batch runtimes.

    Runtime = (#micro - 1 + #stages) * (max_fwd + max_bwd)
              + first_stage_dp_allreduce + max_update
    where maxes run over mask-active stages.
    """
    fwd = np.asarray(fwd, dtype=np.float64)
    bwd = np.asarray(bwd, dtype=np.float64)
    mask = np.asarray(mask, dtype=np.float64)
    update = np.asarray(update, dtype=np.float64)
    c = fwd.shape[0]
    out = np.zeros(c, dtype=np.float64)
    for i in range(c):
        mf = np.max(fwd[i] * mask[i])
        mb = np.max(bwd[i] * mask[i])
        mu = np.max(update[i] * mask[i])
        out[i] = (micro[i] - 1.0 + stages[i]) * (mf + mb) + dp_first[i] + mu
    return out.astype(np.float32)
