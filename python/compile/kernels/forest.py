"""Layer-1 Pallas kernel: batched tree-ensemble (RandomForest / GBT) inference.

This is the hot-spot of the prediction system: sweeping thousands of
candidate (model, parallelism, platform) configurations means millions of
per-operator regressor evaluations. The paper runs sklearn on CPU; we
re-think the traversal for a TPU-style vector unit (DESIGN.md
§Hardware-Adaptation):

- GPU-style thread-per-query traversal is divergent; instead we advance
  ALL queries x ALL trees one level per step (level-synchronous), with
  vectorized gathers and masked leaf lanes — a fixed D-step schedule with
  no data-dependent control flow.
- The flattened forest (feat/thresh/left/right/value, each [T, N]) is
  VMEM-resident; query blocks [BB, F] stream HBM->VMEM over a 1-D grid.
- Tree weights fold RF averaging and GBT learning-rate into a single dot.

Forest tensor layout (produced by rust `forest::export`):
  node_feat[t, n]  int32   feature index of node n in tree t; LEAF(-1) if leaf
  thresh[t, n]     float32 split threshold (go left iff x[f] <= thresh)
  left/right[t, n] int32   child node indices (within tree t)
  value[t, n]      float32 leaf prediction (0 for internal nodes)
  tree_w[t]        float32 per-tree weight (1/k for RF, lr or 0-padding for GBT)

Kernel is executed with interpret=True: CPU PJRT cannot run Mosaic
custom-calls, and correctness is what we validate here (see ref.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import shapes


def _forest_kernel(feat_ref, nf_ref, th_ref, lf_ref, rt_ref, val_ref, w_ref,
                   out_ref, *, depth: int):
    """One grid step: predict a [BB] block of queries against the full forest."""
    feat = feat_ref[...]                      # [BB, F]
    nf = nf_ref[...]                          # [T, N] int32
    th = th_ref[...]                          # [T, N]
    lf = lf_ref[...]                          # [T, N] int32
    rt = rt_ref[...]                          # [T, N] int32
    val = val_ref[...]                        # [T, N]
    w = w_ref[...]                            # [T]

    bb = feat.shape[0]
    t_count, n_count = nf.shape
    f_count = feat.shape[1]

    # Linearized gather helpers: node tables flatten to [T*N]; a (query,
    # tree) cursor matrix idx[bb, T] linearizes as t*N + idx.
    nf_flat = nf.reshape(-1)
    th_flat = th.reshape(-1)
    lf_flat = lf.reshape(-1)
    rt_flat = rt.reshape(-1)
    val_flat = val.reshape(-1)
    tree_base = (jnp.arange(t_count, dtype=jnp.int32) * n_count)[None, :]

    def level(_, idx):
        lin = tree_base + idx                                   # [bb, T]
        node_f = jnp.take(nf_flat, lin, axis=0)                 # [bb, T]
        node_t = jnp.take(th_flat, lin, axis=0)
        node_l = jnp.take(lf_flat, lin, axis=0)
        node_r = jnp.take(rt_flat, lin, axis=0)
        # Gather the split feature per (query, tree); clamp leaf markers.
        f_idx = jnp.clip(node_f, 0, f_count - 1)
        x = jnp.take_along_axis(feat, f_idx, axis=1)            # [bb, T]
        go_left = x <= node_t
        nxt = jnp.where(go_left, node_l, node_r)
        is_leaf = node_f == shapes.LEAF
        return jnp.where(is_leaf, idx, nxt)

    idx0 = jnp.zeros((bb, t_count), dtype=jnp.int32)
    idx = jax.lax.fori_loop(0, depth, level, idx0)

    leaf_val = jnp.take(val_flat, tree_base + idx, axis=0)      # [bb, T]
    out_ref[...] = leaf_val @ w                                  # [bb]


def forest_infer(feat, node_feat, thresh, left, right, value, tree_w,
                 *, block_b: int = shapes.BB, depth: int = shapes.D):
    """Batched forest inference via the Pallas kernel (interpret mode).

    feat: [B, F] float32; forest tensors as module docstring; returns [B].
    """
    b, _f = feat.shape
    t, n = node_feat.shape
    assert b % block_b == 0, (b, block_b)
    grid = (b // block_b,)
    kernel = functools.partial(_forest_kernel, depth=depth)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, feat.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((t, n), lambda i: (0, 0)),
            pl.BlockSpec((t, n), lambda i: (0, 0)),
            pl.BlockSpec((t, n), lambda i: (0, 0)),
            pl.BlockSpec((t, n), lambda i: (0, 0)),
            pl.BlockSpec((t, n), lambda i: (0, 0)),
            pl.BlockSpec((t,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(feat, node_feat, thresh, left, right, value, tree_w)
